"""Quickstart: the cuConv public API in 30 lines.

Runs one convolution through every registered executor (library
baseline, explicit GEMM, the paper's two-stage cuConv, the fused
beyond-paper variant, and the Pallas TPU kernel in interpret mode) and
checks they agree; then uses the cuDNN-style per-layer autotuner.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import conv2d
from repro.core import executors
from repro.core.autotune import select_algorithm, measure_algorithm

rng = np.random.default_rng(0)
# the paper's headline configuration: 7x7x832 input, 256 1x1 filters,
# batch 1 (GoogleNet inception 5a) — cuConv's 2.29x region on V100
x = jnp.asarray(rng.normal(size=(1, 7, 7, 832)), jnp.float32)
w = jnp.asarray(rng.normal(size=(1, 1, 832, 256)), jnp.float32)

ref = conv2d(x, w, algorithm="lax")
print(f"output shape: {ref.shape}")
for name in executors.names():      # the registered executor menu
    out = conv2d(x, w, algorithm=name)
    err = float(jnp.abs(out - ref).max())
    print(f"  {name:24s} max_err_vs_library = {err:.2e}")

heur = select_algorithm(x.shape, w.shape)
best = measure_algorithm(x, w)
print(f"autotune heuristic: {heur}   measured best on this machine: {best}")
