"""Quickstart: the cuConv public API in 40 lines.

Runs one convolution through every registered executor (library
baseline, explicit GEMM, the paper's two-stage cuConv, the fused
beyond-paper variant, and the Pallas TPU kernel in interpret mode) and
checks they agree; then uses the cuDNN-style per-layer autotuner — both
the algorithm sweep and the per-configuration *launch-config* sweep
(tile geometry per convolution configuration, the paper's own
config-selection lever).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import conv2d
from repro.core import executors
from repro.core.autotune import select_algorithm, measure_algorithm
from repro.core.convspec import ConvSpec, plan

rng = np.random.default_rng(0)
# the paper's headline configuration: 7x7x832 input, 256 1x1 filters,
# batch 1 (GoogleNet inception 5a) — cuConv's 2.29x region on V100
x = jnp.asarray(rng.normal(size=(1, 7, 7, 832)), jnp.float32)
w = jnp.asarray(rng.normal(size=(1, 1, 832, 256)), jnp.float32)

ref = conv2d(x, w, algorithm="lax")
print(f"output shape: {ref.shape}")
for name in executors.names():      # the registered executor menu
    out = conv2d(x, w, algorithm=name)
    err = float(jnp.abs(out - ref).max())
    print(f"  {name:24s} max_err_vs_library = {err:.2e}")

heur = select_algorithm(x.shape, w.shape)
best = measure_algorithm(x, w)
print(f"autotune heuristic: {heur}   measured best on this machine: {best}")

# launch-config tuning: sweep the 1x1 Pallas kernel's VMEM-feasible tile
# geometries for THIS configuration and persist the (algorithm, config)
# winner — a later plan() replays it from cache with zero re-measurement
tuned = plan(ConvSpec.for_conv(x, w), force="conv1x1_pallas", tune="full")
print(f"tuned launch config: {tuned.algorithm} "
      f"cfg[{tuned.config_source}]={tuned.config.key()}")
replay = plan(ConvSpec.for_conv(x, w), force="conv1x1_pallas")
assert replay.config == tuned.config and replay.config_source == "measured"
