"""Batched serving example: continuous-batching engine over a small LM.

  PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-1.3b]

Submits 10 requests onto 4 slots (wave-based continuous batching),
decodes greedily, prints per-request outputs and throughput.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config, list_archs, smoke_variant
from repro.models import lm
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=10)
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch))
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=4, max_len=64)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size,
                                               16).astype(np.int32),
                           max_new_tokens=12))
    t0 = time.perf_counter()
    done = eng.run(prompt_len=16)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    for r in sorted(done, key=lambda r: r.rid):
        print(f"  req {r.rid}: {r.out_tokens}")


if __name__ == "__main__":
    main()
