"""End-to-end driver: train a ~100M-param qwen2-family model for a few
hundred steps on synthetic Markov data, with checkpoints + auto-resume.

  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]

``--tiny`` drops to the smoke config for fast CI runs; the default builds
a real ~100M-parameter model (takes a while on 1 CPU core — that is the
point of the full driver).
"""
import argparse
import dataclasses

from repro.configs.base import ModelConfig, get_config, smoke_variant
from repro.data import SyntheticLMData
from repro.train.trainer import Trainer, TrainConfig


def hundred_m() -> ModelConfig:
    # ~100M params: 12L, d_model 768, GQA 12/4 heads, vocab 32k
    return ModelConfig(
        name="qwen2-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=32_000, qkv_bias=True, rope_theta=1e6, grad_accum=1,
        tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    args = ap.parse_args()

    cfg = (dataclasses.replace(smoke_variant(get_config("qwen2-1.5b")),
                               grad_accum=1)
           if args.tiny else hundred_m())
    print(f"model: {cfg.name}  params ~{cfg.num_params()/1e6:.1f}M")
    data = SyntheticLMData(cfg.vocab_size, args.batch,
                           args.seq if not args.tiny else 64, seed=1)
    tcfg = TrainConfig(steps=args.steps, ckpt_every=100,
                       ckpt_dir=args.ckpt_dir, peak_lr=6e-4, log_every=10)
    trainer = Trainer(cfg, tcfg, data)
    final = trainer.run()
    losses = [m["loss"] for m in trainer.metrics_log]
    print(f"loss: first10={sum(losses[:10])/max(len(losses[:10]),1):.3f} "
          f"last10={sum(losses[-10:])/max(len(losses[-10:]),1):.3f}")


if __name__ == "__main__":
    main()
