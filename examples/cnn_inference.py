"""End-to-end CNN inference through the typed operator-IR graph API.

Builds a SqueezeNet-flavoured stack (1x1-heavy: the paper's best region),
plans the WHOLE network once as a GraphPlan (per-node explain table,
one warmup sweep), compares the planned program against the library
convolution, serves a mixed-size request stream through the
batch-bucketed CnnServeEngine — and then does the same for a
ResNet-flavoured network whose residual adds, maxpool and dense head
all execute inside the one planned program (the IR's reason to exist),
including a full bf16 pass under a graph-wide PrecisionPolicy (fp32
master params, fp32 accumulation, precision-distinct plan caches).

  PYTHONPATH=src python examples/cnn_inference.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.convspec import PLAN_STATS, reset_plan_stats
from repro.models.cnn import resnet_like, squeezenet_like
from repro.serve.cnn import CnnServeEngine, ImageRequest

model = squeezenet_like()
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(1, 64, 64, 3)), jnp.float32)

# one planned program for the whole network (resolved once, persisted
# in the graph-level cache keyed by signature + backend)
gp = model.graph_plan((1, 64, 64, 3))
print(gp.explain())
stats = gp.warmup()
print(f"warmup: compiled {len(stats['nodes'])} nodes "
      f"in {stats['total_ms']:.0f} ms\n")

lib = jax.jit(lambda p, x: model.apply(p, x, algorithm="lax"))
auto = jax.jit(lambda p, x: model.apply(p, x))

y_lib = lib(params, x)
y_auto = auto(params, x)
print(f"logits agree: max_err = {float(jnp.abs(y_lib - y_auto).max()):.2e}")

for name, fn in (("library", lib), ("graph-planned", auto)):
    fn(params, x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        fn(params, x).block_until_ready()
    print(f"{name:14s}: {(time.perf_counter()-t0)/5*1e3:.2f} ms/inference")

# batch-bucketed serving: mixed-size requests, two compiled programs
eng = CnnServeEngine(model, params, (64, 64, 3), buckets=(1, 4))
eng.warmup()
for i, n in enumerate([1, 3, 2, 1]):
    eng.submit(ImageRequest(
        rid=i, images=rng.normal(size=(n, 64, 64, 3)).astype(np.float32)))
done = eng.run()
used = {b: n for b, n in eng.stats["batches"].items() if n}
print(f"served {len(done)} requests / {eng.stats['images']} images in "
      f"{sum(used.values())} batches (buckets used: {used}, "
      f"padded slots: {eng.stats['padded_slots']})")

# ---------------------------------------------------------------------------
# a real network shape: residual adds + pooling + head, ONE program
resnet = resnet_like()
rparams = resnet.init(jax.random.PRNGKey(1))
rgp = resnet.graph_plan((1, 32, 32, 3))
print("\n" + rgp.explain())
rgp.warmup()
eng = CnnServeEngine(resnet, rparams, (32, 32, 3), buckets=(1, 4))
eng.warmup()
reset_plan_stats()
for i, n in enumerate([2, 1, 3]):
    eng.submit(ImageRequest(
        rid=i, images=rng.normal(size=(n, 32, 32, 3)).astype(np.float32)))
done = eng.run()
assert PLAN_STATS["resolutions"] == 0, "warm engine must never re-plan"
print(f"resnet_like: served {eng.stats['images']} images through "
      f"{len(eng.compiled_buckets)} planned programs with zero plan() "
      f"resolutions")

# ---------------------------------------------------------------------------
# the same network under a graph-wide bf16 precision policy: every conv
# node plans in bfloat16 (fp32 accumulation per the executors' declared
# behavior), cache keys are dtype-distinct, params stay fp32
bf_gp = resnet.graph_plan((1, 32, 32, 3), precision="bf16")
assert bf_gp.graph.signature() != rgp.graph.signature()
print("\n" + bf_gp.explain())
bf_gp.warmup()
y32 = resnet.apply(rparams, x32 := jnp.asarray(
    rng.normal(size=(1, 32, 32, 3)), jnp.float32))
ybf = resnet.apply(rparams, x32, precision="bf16")
err = float(jnp.abs(y32 - ybf.astype(jnp.float32)).max())
print(f"bf16 vs fp32 logits: max_err = {err:.2e} (bf16 tolerance)")
assert err < 0.05, "bf16 path must stay within bf16 tolerance of fp32"

bf_eng = CnnServeEngine(resnet, rparams, (32, 32, 3), buckets=(1, 4),
                        precision="bf16")
bf_eng.warmup()
for i, n in enumerate([2, 1, 3]):
    bf_eng.submit(ImageRequest(
        rid=i, images=rng.normal(size=(n, 32, 32, 3)).astype(np.float32)))
reset_plan_stats()
bf_eng.run()
assert PLAN_STATS["resolutions"] == 0
print(f"resnet_like[bf16]: served {bf_eng.stats['images']} images through "
      f"{len(bf_eng.compiled_buckets)} planned bf16 programs with zero "
      f"plan() resolutions")
