"""End-to-end CNN inference with per-layer algorithm selection.

Builds a SqueezeNet-flavoured stack (1x1-heavy: the paper's best region),
runs batched inference with (a) the library convolution everywhere and
(b) cuDNN-style per-layer auto-selection over the cuConv family, and
reports agreement + per-layer choices.

  PYTHONPATH=src python examples/cnn_inference.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.convspec import ConvSpec, plan
from repro.models.cnn import SimpleCNN, squeezenet_like

model = squeezenet_like()
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(1, 64, 64, 3)), jnp.float32)

print("per-layer conv plans (input 64x64x3, batch 1, fused bias+ReLU):")
h, c = 64, 3
for i, (kh, kw, co, s) in enumerate(model.spec):
    spec = ConvSpec((1, h, h, c), (kh, kw, c, co), (s, s),
                    ((kh - 1) // 2, (kw - 1) // 2), "float32", "bias_relu")
    p = plan(spec)
    print(f"  layer {i:2d}  {kh}x{kw} {c:4d}->{co:4d} stride {s}:  "
          f"{p.algorithm:8s} [{p.source}] {p.reason}")
    h, c = h // s, co

lib = jax.jit(lambda p, x: model.apply(p, x, algorithm="lax"))
auto = jax.jit(lambda p, x: model.apply(p, x, algorithm="auto"))

y_lib = lib(params, x)
y_auto = auto(params, x)
print(f"logits agree: max_err = {float(jnp.abs(y_lib - y_auto).max()):.2e}")

for name, fn in (("library", lib), ("auto-cuconv", auto)):
    fn(params, x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        fn(params, x).block_until_ready()
    print(f"{name:12s}: {(time.perf_counter()-t0)/5*1e3:.2f} ms/inference")
