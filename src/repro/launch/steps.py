"""Step functions (train / prefill / decode) + ShapeDtypeStruct input specs.

These are the exact callables the dry-run lowers and the real launchers
execute; nothing here allocates device memory.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm
from repro.optim import adamw_update, cosine_schedule


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; weak-type-correct, shardable)

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    batch: Dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        batch["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:
        # modality frontend stub: precomputed frame/patch embeddings
        batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.bfloat16)
    if cfg.mrope_sections:
        batch["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return batch


def state_specs(cfg: ModelConfig, key=None) -> Dict[str, Any]:
    """Abstract train state (params + opt + step) via eval_shape."""
    key = jax.random.PRNGKey(0)

    def build():
        params = lm.init_lm(cfg, key)
        from repro.optim import adamw_init
        return {"params": params, "opt": adamw_init(params),
                "step": jnp.zeros((), jnp.int32)}

    return jax.eval_shape(build)


# ---------------------------------------------------------------------------
# steps

def make_train_step(cfg: ModelConfig, peak_lr=3e-4, total_steps=10_000,
                    act_spec=None, moe_groups=1, grad_compression=False):
    """grad_compression: int8 + error feedback applied to the gradient
    before the optimizer (the EF residual rides in state['ef']); the int8
    payload is what a DCN transport would move cross-pod (dist/compress).
    """
    accum = max(1, cfg.grad_accum)

    def loss_fn(params, micro):
        return lm.train_loss(params, cfg, micro, act_spec=act_spec,
                             moe_groups=moe_groups)

    def train_step(state, batch):
        params = state["params"]
        if accum > 1:
            def split_batch(b):
                out = {}
                for k, v in b.items():
                    if k == "positions" and v.ndim == 3:
                        # (3, B, S): batch axis is dim 1 (M-RoPE layout)
                        a = v.reshape(v.shape[0], accum,
                                      v.shape[1] // accum, v.shape[2])
                        out[k] = jnp.moveaxis(a, 1, 0)
                    else:
                        out[k] = v.reshape((accum, v.shape[0] // accum)
                                           + v.shape[1:])
                return out
            micros = split_batch(batch)

            def body(carry, micro):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, micro)
                return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

            g0 = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32),
                              params)
            (gsum, lsum), _ = jax.lax.scan(body, (g0, jnp.zeros((), jnp.float32)),
                                           micros)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        lr = cosine_schedule(state["step"], peak_lr=peak_lr,
                             total_steps=total_steps)
        extra = {}
        if grad_compression:
            from repro.dist import compress as C
            grads, new_ef = C.tree_quantize_with_feedback(grads, state["ef"])
            extra["ef"] = new_ef
        new_params, new_opt, om = adamw_update(params, grads, state["opt"],
                                               state["step"], lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1, **extra}
        return new_state, {"loss": loss, "lr": lr, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: int, act_spec=None,
                      moe_groups=1):
    def prefill_step(params, batch, cache):
        logits, new_cache = lm.prefill(params, cfg, batch, cache,
                                       act_spec=act_spec,
                                       moe_groups=moe_groups)
        # serving returns only the last-position logits (next-token dist)
        return logits[:, -1, :], new_cache
    return prefill_step


def make_decode_step(cfg: ModelConfig, act_spec=None):
    def decode_step(params, batch, cache, offset):
        logits, new_cache = lm.decode_step(params, cfg, batch, cache, offset,
                                           act_spec=act_spec)
        return logits[:, -1, :], new_cache
    return decode_step
