import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape x mesh) cell this lowers + compiles
the real step function against 512 placeholder CPU devices arranged as
the production mesh, then records memory_analysis / cost_analysis /
per-collective byte counts for the roofline (EXPERIMENTS.md §Dry-run,
§Roofline).  No arrays are ever allocated: params, optimizer state,
caches and batches are ShapeDtypeStructs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
      --shape train_4k [--multi-pod] [--out artifacts/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--resume]
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, get_config, list_archs
from repro.dist import sharding as sh
from repro.launch import steps as St
from repro.launch.mesh import make_production_mesh
from repro.models import lm

# long-context decode is only defined for sub-quadratic archs (DESIGN.md)
LONG_OK_FAMILIES = ("ssm", "hybrid")


def cell_defined(cfg, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return cfg.family in LONG_OK_FAMILIES
    return True


# ---------------------------------------------------------------------------
# collective parsing

_COLL_RE = re.compile(
    r"%(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*=\s*(.+)")

_SHAPE_RE = re.compile(r"(bf16|f32|f16|s32|u32|s8|u8|pred|f64|s64|u64)"
                       r"\[([\d,]*)\]")

_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Sum of *output* shape bytes per collective kind (per-device HLO).

    Partitioned HLO lines look like
      %all-gather.46 = f32[16,4096,1,128]{...} all-gather(%x), ...
    so the output type sits between '=' and the op-kind keyword.  Only
    definition lines (var name matches the kind) are counted, which
    skips -done halves of async pairs and operand mentions.
    """
    out = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind, rhs = m.group(1), m.group(2)
        # rhs starts at the output type; cut at the op keyword
        cut = rhs.find(f" {kind}")
        typ = rhs if cut < 0 else rhs[:cut]
        b = _shape_bytes(typ)
        if b == 0:
            continue
        d = out.setdefault(kind, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += b
    return out


# ---------------------------------------------------------------------------
# lowering per cell

def probe_variant(cfg, n_periods: int):
    """Unrolled small-stack twin of cfg for exact HLO cost accounting.

    XLA's HloCostAnalysis counts while-loop bodies ONCE (verified:
    28-layer vs 14-layer scanned models report equal flops), so costs are
    measured on unrolled 1-period and 2-period stacks and extrapolated
    linearly — exact for flops/bytes/collectives, since every period
    contributes an identical HLO slice.
    """
    import dataclasses
    kw = dict(scan_layers=False, attn_impl="chunked_unrolled", grad_accum=1)
    if cfg.first_layer_dense:
        # probe as uniform MoE stack; layer-0 dense MLP (10944) has nearly
        # the same cost as shared+routed-active (see DESIGN.md note)
        kw["first_layer_dense"] = False
    c0 = dataclasses.replace(cfg, **kw)
    period = c0.pattern_period or 1
    return dataclasses.replace(c0, num_layers=period * n_periods), period


def apply_overrides(cfg, overrides):
    """--set key=value config variants (the §Perf hillclimb entry point)."""
    import dataclasses
    if not overrides:
        return cfg
    kw = {}
    for kv in overrides:
        k, v = kv.split("=", 1)
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            v = v.lower() in ("1", "true", "yes")
        elif isinstance(cur, int):
            v = int(v)
        elif isinstance(cur, float):
            v = float(v)
        kw[k] = v
    return dataclasses.replace(cfg, **kw)


def lower_cell(arch: str, shape_name: str, multi_pod: bool, cfg=None,
               act_seq_shard: bool = False):
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    long_ctx = shape_name == "long_500k"
    rules = sh.make_rules(shape.kind, multi_pod, long_context=long_ctx)

    batch_shapes = St.input_specs(cfg, shape)
    bspecs = sh.batch_specs(batch_shapes, rules)

    nm = lambda tree: sh.named(mesh, tree)
    # sequence-sharded residual stream ("SP"): halves TP collective bytes
    # by turning per-layer all-reduce into reduce-scatter + all-gather
    act_spec = nm(sh.P(rules["batch"], "model", None) if act_seq_shard
                  else sh.P(rules["batch"], None, None))
    dp = (mesh.shape["data"] * mesh.shape.get("pod", 1)
          if not (shape_name == "long_500k") else 1)
    with mesh:
        if shape.kind == "train":
            state_shapes = St.state_specs(cfg)
            pspecs = sh.param_specs(state_shapes["params"], rules)
            sspecs = {"params": pspecs, "opt": sh.opt_specs(pspecs),
                      "step": sh.P()}
            step = St.make_train_step(cfg, act_spec=act_spec, moe_groups=dp)
            jitted = jax.jit(step,
                             in_shardings=(nm(sspecs), nm(bspecs)),
                             out_shardings=(nm(sspecs), None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_shapes, batch_shapes)
        else:
            params_shapes = jax.eval_shape(
                lambda: lm.init_lm(cfg, jax.random.PRNGKey(0)))
            pspecs = sh.param_specs(params_shapes, rules)
            cache_shapes = lm.cache_shapes(cfg, shape.global_batch,
                                           shape.seq_len)
            cspecs = sh.cache_specs(cache_shapes, cfg, rules)
            logit_spec = sh.P(rules["batch"], "model")
            if shape.kind == "prefill":
                step = St.make_prefill_step(cfg, shape.seq_len,
                                            act_spec=act_spec, moe_groups=dp)
                jitted = jax.jit(step,
                                 in_shardings=(nm(pspecs), nm(bspecs),
                                               nm(cspecs)),
                                 out_shardings=(nm(logit_spec), nm(cspecs)),
                                 donate_argnums=(2,))
                lowered = jitted.lower(params_shapes, batch_shapes,
                                       cache_shapes)
            else:
                step = St.make_decode_step(cfg, act_spec=act_spec)
                jitted = jax.jit(step,
                                 in_shardings=(nm(pspecs), nm(bspecs),
                                               nm(cspecs), nm(sh.P())),
                                 out_shardings=(nm(logit_spec), nm(cspecs)),
                                 donate_argnums=(2,))
                offset = jax.ShapeDtypeStruct((), jnp.int32)
                lowered = jitted.lower(params_shapes, batch_shapes,
                                       cache_shapes, offset)
        compiled = lowered.compile()
    return cfg, shape, mesh, lowered, compiled


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             verbose=True, overrides=None, act_seq_shard=False,
             variant: str = ""):
    t0 = time.time()
    cfg = apply_overrides(get_config(arch), overrides)
    tag = f"{arch}__{shape_name}__{'multipod' if multi_pod else 'pod'}"
    if variant:
        tag += f"__{variant}"
    rec = {"arch": arch, "shape": shape_name, "variant": variant,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not cell_defined(cfg, shape_name):
        rec["status"] = "SKIP(full-attn)"
        (out_dir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
        print(f"[dryrun] {tag}: SKIP (full-attention arch, long_500k "
              "needs sub-quadratic path; see DESIGN.md)")
        return rec
    try:
        cfg, shape, mesh, lowered, compiled = lower_cell(
            arch, shape_name, multi_pod, cfg=cfg,
            act_seq_shard=act_seq_shard)
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        colls = collective_bytes(hlo)
        n_dev = mesh.devices.size

        # cost probes: unrolled 1- and 2-period stacks -> exact totals
        probe = {}
        try:
            pc1, period = probe_variant(cfg, 1)
            pc2, _ = probe_variant(cfg, 2)
            n_periods = cfg.num_layers // period
            *_, comp1 = lower_cell(arch, shape_name, multi_pod, cfg=pc1,
                                   act_seq_shard=act_seq_shard)
            *_, comp2 = lower_cell(arch, shape_name, multi_pod, cfg=pc2,
                                   act_seq_shard=act_seq_shard)
            c1, c2 = comp1.cost_analysis(), comp2.cost_analysis()
            cb1 = collective_bytes(comp1.as_text())
            cb2 = collective_bytes(comp2.as_text())
            ext = lambda a, b: a + (n_periods - 1) * (b - a)
            probe = {
                "period": period,
                "n_periods": n_periods,
                "flops_total_per_device": ext(c1.get("flops", 0.0),
                                              c2.get("flops", 0.0)),
                "bytes_total_per_device": ext(c1.get("bytes accessed", 0.0),
                                              c2.get("bytes accessed", 0.0)),
                "collective_bytes_total_per_device": ext(
                    sum(v["bytes"] for v in cb1.values()),
                    sum(v["bytes"] for v in cb2.values())),
                "collectives_by_kind": {
                    k: ext(cb1.get(k, {}).get("bytes", 0),
                           cb2.get(k, {}).get("bytes", 0))
                    for k in set(cb1) | set(cb2)},
            }
        except Exception as pe:  # noqa: BLE001
            probe = {"error": f"{type(pe).__name__}: {pe}"}

        rec.update({
            "status": "OK",
            "devices": n_dev,
            "compile_s": round(time.time() - t0, 1),
            "flops_per_device": cost.get("flops", 0.0),
            "bytes_accessed_per_device": cost.get("bytes accessed", 0.0),
            "collectives": colls,
            "collective_bytes_per_device": sum(
                v["bytes"] for v in colls.values()),
            "probe": probe,
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0)
                               + getattr(mem, "argument_size_in_bytes", 0)),
            },
            "params": cfg.num_params(),
            "active_params": cfg.num_active_params(),
            "tokens": shape.global_batch * (shape.seq_len
                                            if shape.kind != "decode" else 1),
            "kind": shape.kind,
        })
        if verbose:
            print(f"[dryrun] {tag}: OK in {rec['compile_s']}s  "
                  f"flops/dev={rec['flops_per_device']:.3e}  "
                  f"bytes/dev={rec['bytes_accessed_per_device']:.3e}  "
                  f"coll_bytes/dev={rec['collective_bytes_per_device']:.3e}  "
                  f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB")
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["status"] = f"FAIL: {type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {tag}: FAIL {type(e).__name__}: {str(e)[:300]}")
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose artifact already exists and is OK")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--set", dest="overrides", action="append", default=[],
                    help="config override key=value (repeatable); "
                         "e.g. --set ce_impl=chunked --set remat=dots")
    ap.add_argument("--act-seq-shard", action="store_true",
                    help="sequence-shard the residual stream over 'model'")
    ap.add_argument("--variant", default="",
                    help="tag appended to the artifact name")
    args = ap.parse_args(argv)
    out_dir = Path(args.out)

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multipod' if mp else 'pod'}"
                if args.variant:
                    tag += f"__{args.variant}"
                f = out_dir / f"{tag}.json"
                if args.resume and f.exists():
                    rec = json.loads(f.read_text())
                    if rec.get("status", "").startswith(("OK", "SKIP")):
                        print(f"[dryrun] {tag}: cached ({rec['status']})")
                        results.append(rec)
                        continue
                results.append(run_cell(
                    arch, shape, mp, out_dir, overrides=args.overrides,
                    act_seq_shard=args.act_seq_shard, variant=args.variant))
    bad = [r for r in results if r["status"].startswith("FAIL")]
    print(f"[dryrun] done: {len(results)} cells, {len(bad)} failures")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
