"""Training launcher.

Single-host CPU runs smoke-scale jobs end-to-end; on a pod the same
entry point runs under `jax.distributed` (one process per host) with the
production mesh — the step function, sharding rules, data pipeline and
checkpoints are identical (the data pipeline is a pure function of
(seed, step) so every host computes its own shard of every batch, and
checkpoints restore elastically onto whatever mesh comes up).

Straggler/preemption protocol (multi-host attach points):
  * per-step deadline: Trainer records steps slower than k x median; a
    pod launcher pairs this with a health server to evict the slow host;
  * preemption: SIGTERM -> final sync checkpoint -> exit 0; the cluster
    scheduler restarts the job, which auto-resumes from the last step;
  * elastic restart: checkpoints are mesh-independent (gathered + hashed)
    so a 512-chip job can resume on 256 chips (tests/test_checkpoint.py
    exercises mesh A -> mesh B restore).
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import sys

import jax

from repro.configs.base import get_config, list_archs, smoke_variant
from repro.data import SyntheticLMData
from repro.train.trainer import Trainer, TrainConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default=None,
                    help="'debug' for a small local mesh, 'pod'/'multipod' "
                         "for production (requires 256/512 devices)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
        cfg = dataclasses.replace(cfg, grad_accum=1)

    mesh = None
    if args.mesh == "debug":
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh()
    elif args.mesh in ("pod", "multipod"):
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.mesh == "multipod")

    data = SyntheticLMData(cfg.vocab_size, args.batch, args.seq)
    tcfg = TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir, peak_lr=args.lr)
    trainer = Trainer(cfg, tcfg, data, mesh=mesh)

    def on_sigterm(sig, frame):           # preemption: checkpoint + exit
        from repro.train import checkpoint as ckpt
        if trainer.state is not None:
            ckpt.save_checkpoint(tcfg.ckpt_dir, int(trainer.state["step"]),
                                 trainer.state)
        sys.exit(0)

    signal.signal(signal.SIGTERM, on_sigterm)
    final = trainer.run()
    print(f"[train] done: {final}")


if __name__ == "__main__":
    main()
