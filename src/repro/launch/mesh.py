"""Production mesh definitions.

Single pod: (16, 16) = 256 chips, axes ('data', 'model') — TP inside the
fast ICI dimension, FSDP over 'data'.  Multi-pod: (2, 16, 16) = 512
chips, axes ('pod', 'data', 'model') — only gradient all-reduce (train)
or pure batch parallelism (serve) crosses the slow 'pod' (DCN-class)
axis.  Defined as functions so importing this module never touches jax
device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None, model: int = 2):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = n_devices or len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
