"""Production mesh definitions.

Single pod: (16, 16) = 256 chips, axes ('data', 'model') — TP inside the
fast ICI dimension, FSDP over 'data'.  Multi-pod: (2, 16, 16) = 512
chips, axes ('pod', 'data', 'model') — only gradient all-reduce (train)
or pure batch parallelism (serve) crosses the slow 'pod' (DCN-class)
axis.  Serving: a 1-D ('data',) mesh over the host's addressable
devices — CNN inference is embarrassingly batch-parallel, so the
sharded bucket programs (serve/distributed.py) never need a model axis.
Defined as functions so importing this module never touches jax device
state.
"""
from __future__ import annotations

import jax
import numpy as np

#: the one mesh axis the serving layer shards over (batch data-parallel)
SERVE_AXIS = "data"


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None, model: int = 2):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = n_devices or len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_serve_mesh(n_devices: int | None = None):
    """The 1-D local data-parallel serving mesh: axis ``'data'`` over
    this host's addressable devices (the first ``n_devices`` of them).

    Every sharded bucket program shards its batch axis over this mesh
    and replicates params; there is deliberately no model axis — at
    serving batch sizes the collective-free layout wins.  On CPU CI the
    same mesh forms over ``--xla_force_host_platform_device_count=N``
    forced host devices, which is what makes the whole distributed
    subsystem testable without accelerators.
    """
    devs = jax.local_devices()
    n = n_devices or len(devs)
    if not 1 <= n <= len(devs):
        raise ValueError(f"n_devices must be in [1, {len(devs)}]; got {n}")
    return jax.sharding.Mesh(np.array(devs[:n]), (SERVE_AXIS,))
