"""Serving launcher: batched greedy decoding over the ServeEngine,
plus the process_index-disciplined multi-device CNN entry
(``--cnn-dist``)."""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs.base import get_config, list_archs, smoke_variant
from repro.models import lm
from repro.serve import Request, ServeEngine


def cnn_dist_main(args) -> None:
    """One ``ShardedServeDispatcher`` per host.

    Every process derives the same geometry partition from the same
    config (``owned_geometries``: sorted round-robin by
    ``process_index``), so which host admits which image shape is
    decided with no coordination — a request router needs only the
    config and the ownership rule.  This process admits traffic ONLY
    for the geometries it owns; on a single-process deployment that is
    all of them.
    """
    from repro.configs.serve import DIST_SMOKE
    from repro.models.cnn import tiny_cnn
    from repro.serve import ServeRequest, ShardedServeDispatcher

    model = tiny_cnn()
    params = model.init(jax.random.PRNGKey(0))
    disp = ShardedServeDispatcher(
        model, params, DIST_SMOKE.geometry_map(),
        process_index=args.process_index,
        process_count=args.process_count,
        max_wait_ms=DIST_SMOKE.max_wait_ms,
        default_deadline_ms=DIST_SMOKE.default_deadline_ms,
        pipeline_depth=DIST_SMOKE.pipeline_depth)
    print(f"[serve-dist] process {disp.process_index}/"
          f"{disp.process_count}, {disp.n_devices} device(s), owns "
          f"{['x'.join(map(str, s)) for s in disp.geometries] or 'nothing'}")
    if not disp.geometries:
        return
    disp.warmup()
    rng = np.random.default_rng(disp.process_index)
    t0 = time.perf_counter()
    rid = 0
    for _ in range(args.requests):
        shape = disp.geometries[rid % len(disp.geometries)]
        n = int(rng.integers(1, max(disp.global_buckets(shape)) + 1))
        disp.submit(ServeRequest(
            rid=rid, images=rng.standard_normal((n,) + shape,
                                                dtype=np.float32)))
        rid += 1
    done = disp.run()
    dt = time.perf_counter() - t0
    images = sum(len(r.images) for r in done)
    print(f"[serve-dist] {len(done)} requests, {images} images in "
          f"{dt*1e3:.1f}ms ({images/dt:.0f} img/s post-warmup)")
    print(json.dumps(disp.stats(), indent=2, default=str))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cnn-dist", action="store_true",
                    help="serve the DIST_SMOKE CNN deployment through "
                         "one per-host ShardedServeDispatcher")
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--process-index", type=int, default=None,
                    help="override jax.process_index() (cnn-dist)")
    ap.add_argument("--process-count", type=int, default=None,
                    help="override jax.process_count() (cnn-dist)")
    args = ap.parse_args(argv)

    if args.cnn_dist:
        return cnn_dist_main(args)
    if args.arch is None:
        ap.error("--arch is required unless --cnn-dist is given")
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new))
    done = eng.run(prompt_len=args.prompt_len)
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)} requests, {tokens} tokens in {dt:.2f}s "
          f"({tokens/dt:.1f} tok/s incl. compile)")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req {r.rid}: {r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
