"""Serving launcher: batched greedy decoding over the ServeEngine."""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import get_config, list_archs, smoke_variant
from repro.models import lm
from repro.serve import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new))
    done = eng.run(prompt_len=args.prompt_len)
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)} requests, {tokens} tokens in {dt:.2f}s "
          f"({tokens/dt:.1f} tok/s incl. compile)")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req {r.rid}: {r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
