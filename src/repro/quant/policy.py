"""QuantPolicy + the int8 graph rewrite pass (DESIGN.md §13).

``QuantPolicy`` extends the graph-wide ``PrecisionPolicy`` — it IS a
precision policy (its ``default`` is the fp fallback dtype every
non-quantized node plans in), plus the quantization *choices*: which
observer derives activation scales, which nodes opt out, whether the
first/last conv stay fp.  The policy holds choices, never data —
calibrated ranges live in ``calibration.json`` and weight scales are
computed per-channel from the weights at execution time, so the policy
stays frozen/hashable and plan-memo keys stay cheap.

``quantize_graph`` is the planning-time rewrite (same shape as
``fuse_graph``): it runs on the pre-fusion IR and flips eligible conv
nodes' ``ConvSpec.dtype`` to int8.  A node quantizes only when every
gate passes — not opted out, not first/last under the fallback rule,
fresh calibration present, and at least one registered executor
supports the int8 spec.  Every decision is recorded as a ``NodeQuant``
so ``explain()`` can show per-node provenance (``int8<-calib:absmax``
vs ``fp:no-calibration`` etc.).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.graph import Graph, ConvOp, PrecisionPolicy
from repro.quant import calibrate, symmetric


@dataclasses.dataclass(frozen=True)
class QuantInfo:
    """Per-node execution payload: the calibrated per-tensor activation
    scale the int8 executor quantizes inputs with (weights get
    per-channel scales from the weight values themselves)."""
    x_scale: float
    source: str                  # calib:absmax | calib:pct99.9 | dynamic

    def key(self) -> str:
        return f"{self.source}:{self.x_scale:.6g}"


@dataclasses.dataclass(frozen=True)
class NodeQuant:
    """Per-node quantization provenance for ``explain()``/reporting."""
    dtype: str                   # int8 | the fp dtype the node kept
    source: str                  # scale source, or the fp-fallback reason
    x_scale: Optional[float] = None

    @property
    def quantized(self) -> bool:
        return self.dtype == "int8"

    def label(self) -> str:
        return (f"int8<-{self.source}" if self.quantized
                else self.source)


@dataclasses.dataclass(frozen=True)
class QuantPolicy(PrecisionPolicy):
    """Int8 inference policy: fp fallback dtype + quantization choices.

    ``QuantPolicy()`` quantizes every eligible conv node to int8 with
    fp32 fallback; ``QuantPolicy("bf16")`` falls back to bf16 instead.
    ``skip`` opts named conv nodes out; ``skip_first_last`` (default
    True) keeps the first and last conv in fp — the standard accuracy
    guard (input statistics are unclipped, the head feeds logits).
    ``observer`` picks which calibrated statistic activation scales
    derive from (``"absmax"`` | ``"percentile"``).
    """
    quant_dtype: str = "int8"
    skip: Tuple[str, ...] = ()
    skip_first_last: bool = True
    observer: str = "absmax"
    percentile: float = 99.9

    def __post_init__(self):
        super().__post_init__()
        if self.quant_dtype != "int8":
            raise ValueError(
                f"only int8 quantization is supported; got "
                f"{self.quant_dtype!r}")
        if self.observer not in calibrate.Calibrator.OBSERVERS:
            raise ValueError(
                f"observer must be one of {calibrate.Calibrator.OBSERVERS};"
                f" got {self.observer!r}")
        object.__setattr__(self, "skip",
                           tuple(sorted(str(s) for s in self.skip)))
        object.__setattr__(self, "percentile", float(self.percentile))

    def quantizer(self) -> "QuantPolicy":
        """Quant policies quantize; plain precision policies return
        None here — the hook ``plan_graph`` threading keys off."""
        return self

    def key(self) -> str:
        base = super().key()
        skip = ",".join(self.skip)
        return (f"{base}+{self.quant_dtype}[obs={self.observer}"
                f"@{self.percentile:g},fl={int(self.skip_first_last)}"
                f"{',skip=' + skip if skip else ''}]")

    def skips(self, name: str, first: Optional[str], last: Optional[str]
              ) -> Optional[str]:
        """The fp-fallback reason for this node, or None (eligible)."""
        if name in self.skip:
            return "fp:skip"
        if self.skip_first_last and name == first:
            return "fp:first"
        if self.skip_first_last and name == last:
            return "fp:last"
        return None


def quantize_graph(ir: Graph, policy: QuantPolicy,
                   backend: Optional[str] = None
                   ) -> Tuple[Graph, Dict[str, NodeQuant],
                              Dict[str, QuantInfo]]:
    """Rewrite eligible conv nodes to int8 specs (planning-time pass).

    Runs on the PRE-fusion IR (calibration entries are keyed by it;
    fusion then rewrites the quantized graph, so fused int8 specs carry
    the int8 dtype in their cache keys by construction).  Returns
    ``(graph, provenance, qinfos)`` — provenance covers EVERY conv node
    (quantized or the reason it stayed fp); ``qinfos`` only the
    quantized ones (the execution payload ``plan_graph`` attaches to
    each node's ConvPlan).  The input graph object is returned
    unchanged when nothing quantizes.
    """
    from repro.core import executors
    convs = [n for n in ir.nodes if isinstance(n, ConvOp)]
    first = convs[0].name if convs else None
    last = convs[-1].name if convs else None
    nodes = list(ir.nodes)
    prov: Dict[str, NodeQuant] = {}
    qinfos: Dict[str, QuantInfo] = {}
    changed = False
    for i, node in enumerate(nodes):
        if not isinstance(node, ConvOp):
            continue
        name, spec = node.name, node.spec
        reason = policy.skips(name, first, last)
        if reason is not None:
            prov[name] = NodeQuant(spec.dtype, reason)
            continue
        entry = calibrate.calibration_entry(ir, name)
        if entry is None:
            prov[name] = NodeQuant(spec.dtype, "fp:no-calibration")
            continue
        if entry.get("spec") != calibrate.normalized_spec(spec):
            # the node changed under a colliding name since calibration
            # was taken: a scale for a different tensor must never serve
            prov[name] = NodeQuant(spec.dtype, "fp:stale-calibration")
            continue
        qspec = dataclasses.replace(spec, dtype="int8")
        if not executors.supporting(qspec):
            prov[name] = NodeQuant(spec.dtype, "fp:unsupported")
            continue
        amax, source = calibrate.scale_source(entry, policy.observer,
                                              policy.percentile)
        x_scale = float(symmetric.scale_for(amax))
        nodes[i] = ConvOp(name, node.inputs, qspec)
        prov[name] = NodeQuant("int8", source, x_scale)
        qinfos[name] = QuantInfo(x_scale, source)
        changed = True
    if not changed:
        return ir, prov, qinfos
    return (Graph(tuple(nodes), ir.in_shape, ir.input_name, ir.output),
            prov, qinfos)
