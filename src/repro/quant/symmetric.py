"""Symmetric int8 scale/clip/round core.

The ONE place the repo maps float tensors onto the signed-127 grid —
shared by the gradient-compression path (``dist/compress.py``, per-block
scales) and the inference quantizer (``quant/``, per-channel weight and
per-tensor activation scales), so the two int8 paths cannot drift.

Convention: symmetric around zero with the -128 code unused, i.e.
``q = clip(round(x / scale), -127, 127)`` with ``scale = amax / 127``.
A zero ``amax`` (all-zero tensor/block/channel) quantizes to all zeros
through a guarded divisor, and dequantizing with the *unguarded* zero
scale is exact — the guard never leaks into the wire format.
"""
from __future__ import annotations

import jax.numpy as jnp

#: largest magnitude representable: symmetric grid, -128 unused
QMAX = 127.0


def scale_for(amax):
    """Symmetric int8 scale for a known absolute maximum."""
    return amax / QMAX


def safe_scale(scale):
    """Divisor-safe view of a scale: zero scales divide as 1.0 (the
    quantized values are all zero either way)."""
    return jnp.where(scale > 0, scale, 1.0)


def quantize_to_int8(x, scale):
    """``clip(round(x / scale), -127, 127)`` as int8, zero-scale safe."""
    return jnp.clip(jnp.round(x / safe_scale(scale)),
                    -QMAX, QMAX).astype(jnp.int8)


def dequantize_int8(q, scale):
    """Back to fp32; no zero-guard needed — a zero scale means the
    values quantized to all zeros, and 0 * 0 is already right."""
    return q.astype(jnp.float32) * scale


def abs_max(x, axis=None, keepdims: bool = False):
    """max|x| in fp32 — the amax every symmetric scale derives from."""
    return jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis,
                   keepdims=keepdims)


def channel_scales(w):
    """Per-output-channel symmetric scales for an HWIO filter.

    Returns shape ``(M,)`` fp32: ``max|w[..., m]| / 127`` — the
    per-channel weight grid the int8 executor dequantizes through.
    """
    return scale_for(abs_max(w, axis=tuple(range(w.ndim - 1))))
