"""Quantization accuracy harness: bounded output error vs fp32.

Two surfaces:

  * ``accuracy_report`` / ``assert_accuracy`` — whole-network: run the
    QuantPolicy-planned graph and the fp32 graph of the same model on
    the same inputs and compare final outputs (the CI ``int8-smoke``
    gate and the end-to-end tests ride this).
  * ``spec_accuracy`` — per-layer: one int8 ConvSpec vs its fp32 twin
    on random operands (the paper-table benchmark's accuracy-delta
    column rides this).

The documented bound (``DEFAULT_BOUND``, relative to the fp32 output's
absolute max) covers symmetric per-tensor activation + per-channel
weight quantization on calibrated data: each int8 grid contributes at
most ``amax/254`` per element, and the fp32 requantization epilogue
adds no further error.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

#: documented relative-error bound (vs the fp32 output's abs max) for
#: calibrated int8 inference — asserted by tests and the CI smoke
DEFAULT_BOUND = 0.05


def _rel_err(y_q, y_fp) -> dict:
    y_q = np.asarray(y_q, np.float32)
    y_fp = np.asarray(y_fp, np.float32)
    ref = float(np.abs(y_fp).max())
    abs_err = float(np.abs(y_q - y_fp).max())
    return {"abs_err": abs_err, "ref_absmax": ref,
            "rel_err": abs_err / (ref + 1e-12)}


def accuracy_report(model, params, x, policy=None,
                    backend: Optional[str] = None) -> dict:
    """Quantized-vs-fp32 output error for one model + input batch.

    ``policy`` defaults to ``QuantPolicy()`` (int8, fp32 fallback,
    absmax observer).  Returns the error stats plus per-node quant
    provenance — which nodes ran int8 and why the rest stayed fp.
    """
    from repro.core.graph import PrecisionPolicy
    from repro.quant.policy import QuantPolicy
    policy = policy if policy is not None else QuantPolicy()
    gp_fp = model.graph_plan(x.shape, backend=backend,
                             precision=PrecisionPolicy("float32"))
    gp_q = model.graph_plan(x.shape, backend=backend, precision=policy)
    rep = _rel_err(gp_q.run(x, params), gp_fp.run(x, params))
    rep["quantized_nodes"] = sorted(
        n for n, q in gp_q.quant.items() if q.quantized)
    rep["fp_nodes"] = {n: q.source for n, q in gp_q.quant.items()
                       if not q.quantized}
    rep["bound"] = DEFAULT_BOUND
    return rep


def assert_accuracy(model, params, x, policy=None,
                    bound: float = DEFAULT_BOUND,
                    backend: Optional[str] = None) -> dict:
    """``accuracy_report`` that raises when the bound is exceeded;
    returns the report so callers can log it."""
    rep = accuracy_report(model, params, x, policy=policy, backend=backend)
    if rep["rel_err"] > bound:
        raise AssertionError(
            f"int8 output error {rep['rel_err']:.4f} exceeds the "
            f"documented bound {bound} (abs {rep['abs_err']:.4f} vs "
            f"fp32 absmax {rep['ref_absmax']:.4f}; quantized nodes: "
            f"{rep['quantized_nodes']})")
    return rep


def spec_accuracy(spec, seed: int = 0) -> dict:
    """Per-layer int8-vs-fp32 error for one ConvSpec on random operands
    (unit-normal activations, 0.1-std weights — the benchmark regime).

    ``spec`` may be fp or int8; both variants are derived from it.
    """
    import dataclasses
    import jax.numpy as jnp
    from repro.core import convspec as cs
    rng = np.random.default_rng(seed)
    fp = dataclasses.replace(spec, dtype="float32")
    q8 = dataclasses.replace(spec, dtype="int8")
    x = jnp.asarray(rng.standard_normal(fp.in_shape), jnp.float32)
    w = jnp.asarray(rng.standard_normal(fp.filter_shape) * 0.1, jnp.float32)
    b = (jnp.asarray(rng.standard_normal((fp.filter_shape[3],)) * 0.1,
                     jnp.float32) if fp.has_bias else None)
    a = (jnp.asarray(rng.standard_normal(fp.out_shape), jnp.float32)
         if fp.fused_add != "none" else None)
    y_fp = cs.plan(fp)(x, w, b, a)
    y_q = cs.plan(q8)(x, w, b, a)
    return _rel_err(y_q, y_fp)
