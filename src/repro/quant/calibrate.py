"""Per-node activation range calibration, persisted across processes.

``GraphPlan.warmup(calibrate=Calibrator(x, params))`` runs the fp graph
over a caller-supplied sample batch and records, for every conv node,
the absolute range of its INPUT activation — both observers at once:

  * ``absmax`` — max|x| over the batch (exact, outlier-sensitive);
  * ``percentile`` — the 99.9th percentile of |x| (clips outliers for a
    tighter int8 grid; which observer the scale *uses* is the
    ``QuantPolicy.observer`` choice, made at quantize time).

Entries persist in a schema-versioned ``calibration.json`` (the same
``JsonCache`` machinery as autotune.json / graphplans.json) keyed by
**batch- and dtype-normalized graph signature + node name**, so a
calibration taken at batch 8 in fp32 serves every serving bucket size
and every fp fallback dtype of the same architecture::

    {"schema": 1, "spec": "n*h32w32c3-k3x3m16-s1x1-p1x1-*-bias_relu",
     "amax": 4.37, "pct": {"99.9": 3.91}, "batches": 2, "samples": 16}

Unversioned or foreign-schema entries are dropped on read (the
autotune.json v2 contract); an entry whose recorded normalized spec no
longer matches the node is **stale** — the node falls back to fp until
recalibrated (``quantize_graph`` reports ``fp:stale-calibration``).
"""
from __future__ import annotations

import hashlib
import re
from typing import Any, Dict, Optional

import numpy as np

from repro.core.plancache import JsonCache

#: persisted-entry schema; bump when the entry shape changes
CALIB_SCHEMA = 1

_STORE = JsonCache("calibration.json")

#: observable collection effort — tests assert replay performs zero
#: collection passes
CALIB_STATS = {"collections": 0, "observed_nodes": 0}

# monotone generation counter: bumped on every persist so plan memos
# keyed on it re-resolve after a recalibration
_GENERATION = [0]

_BATCH_RE = re.compile(r"(?:(?<=:)|^)n\d+h")     # conv key batch dim
_INSHAPE_RE = re.compile(r"in\(\d+,")            # graph input batch dim
_DTYPE_RE = re.compile(r"-(float\d+|bfloat16|int8)-")


def generation() -> int:
    """Bumped on every persisted calibration — memo-staleness token."""
    return _GENERATION[0]


def clear_cache() -> None:
    """Drop the in-memory mirror (tests); the JSON file is untouched."""
    _STORE.clear()


def reset_calib_stats() -> dict:
    old = dict(CALIB_STATS)
    for k in CALIB_STATS:
        CALIB_STATS[k] = 0
    return old


def normalized_spec(spec) -> str:
    """A ConvSpec key with batch and dtype wildcarded — activation
    ranges depend on neither."""
    key = _BATCH_RE.sub("n*h", spec.key())
    return _DTYPE_RE.sub("-*-", key)


def graph_key(graph) -> str:
    """Batch/dtype-normalized graph identity for calibration keying.

    Same architecture at batch 1 vs 8, fp32 vs bf16 -> same key; any
    structural change (node set, shapes, epilogues) -> different key.
    """
    blob = "|".join([f"v{CALIB_SCHEMA}", f"in{tuple(graph.in_shape)}",
                     f"out:{graph.output}"]
                    + [n.descriptor() for n in graph.nodes])
    blob = _INSHAPE_RE.sub("in(*,", blob)
    blob = _BATCH_RE.sub("n*h", blob)
    blob = _DTYPE_RE.sub("-*-", blob)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def _entry_key(graph, node_name: str) -> str:
    return f"{graph_key(graph)}/{node_name}"


def calibration_entry(graph, node_name: str) -> Optional[dict]:
    """The persisted, schema-gated entry for this node, or None.

    Unversioned / foreign-schema / malformed entries are dropped here —
    never misdecoded into a scale.
    """
    e = _STORE.get(_entry_key(graph, node_name))
    if not isinstance(e, dict) or e.get("schema") != CALIB_SCHEMA:
        return None
    if not isinstance(e.get("amax"), (int, float)):
        return None
    return e


def record_calibration(graph, node_name: str, spec, amax: float,
                       pct: Dict[str, float], samples: int) -> dict:
    """Persist (merging with any prior batch: running max — the
    conservative union of observed ranges).  Returns the stored entry.
    """
    key = _entry_key(graph, node_name)
    prev = calibration_entry(graph, node_name)
    entry = {"schema": CALIB_SCHEMA, "spec": normalized_spec(spec),
             "amax": float(amax),
             "pct": {k: float(v) for k, v in pct.items()},
             "batches": 1, "samples": int(samples)}
    if prev is not None and prev.get("spec") == entry["spec"]:
        entry["amax"] = max(entry["amax"], float(prev["amax"]))
        for k, v in (prev.get("pct") or {}).items():
            if k in entry["pct"]:
                entry["pct"][k] = max(entry["pct"][k], float(v))
        entry["batches"] = int(prev.get("batches", 0)) + 1
        entry["samples"] = int(prev.get("samples", 0)) + entry["samples"]
    _STORE.put(key, entry)
    _GENERATION[0] += 1
    return entry


class Calibrator:
    """A sample batch + parameters + observer choice, handed to
    ``GraphPlan.warmup(calibrate=...)``.

    ``observer`` names which recorded statistic the quantizer should
    derive activation scales from: ``"absmax"`` or ``"percentile"``
    (the entry always records both).
    """

    OBSERVERS = ("absmax", "percentile")

    def __init__(self, x, params, observer: str = "absmax",
                 percentile: float = 99.9):
        if observer not in self.OBSERVERS:
            raise ValueError(
                f"observer must be one of {self.OBSERVERS}; got {observer!r}")
        self.x = x
        self.params = params
        self.observer = observer
        self.percentile = float(percentile)

    def collect(self, graph_plan) -> Dict[str, dict]:
        """Run the plan over the sample batch, observing every conv
        node's input activation; persist and return the entries.

        Keys by the plan's PRE-fusion graph (fusion never changes a
        conv node's input), so the quantize pass — which rewrites the
        pre-fusion IR — finds what warmup recorded.
        """
        key_graph = graph_plan.base_graph or graph_plan.graph
        # record PRE-fusion specs: the quantize pass (which rewrites the
        # pre-fusion IR) validates entries against them, and fusion
        # suffixes must not read as staleness
        specs = {n.name: n.spec for n in key_graph.nodes
                 if getattr(n, "op", None) == "conv"}
        observed: Dict[str, Any] = {}

        def observe(name, value):
            if name in specs:
                observed[name] = np.abs(np.asarray(value, np.float32))

        CALIB_STATS["collections"] += 1
        graph_plan.run(self.x, self.params, observe=observe)
        pct_key = f"{self.percentile:g}"
        entries = {}
        for name, mag in observed.items():
            CALIB_STATS["observed_nodes"] += 1
            entries[name] = record_calibration(
                key_graph, name, specs[name],
                amax=float(mag.max()) if mag.size else 0.0,
                pct={pct_key: float(np.percentile(mag, self.percentile))
                     if mag.size else 0.0},
                samples=int(np.shape(self.x)[0]))
        return entries


def scale_source(entry: dict, observer: str, percentile: float = 99.9
                 ) -> tuple:
    """(amax, provenance string) for the chosen observer — falls back
    to absmax when the recorded percentile key is missing."""
    if observer == "percentile":
        pct = entry.get("pct") or {}
        key = f"{percentile:g}"
        if key in pct:
            return float(pct[key]), f"calib:pct{key}"
    return float(entry["amax"]), "calib:absmax"
