"""Int8 quantized inference subsystem (DESIGN.md §13).

Takes a planned CNN graph from fp32/bf16 to served int8 with no API
break: calibration observers collect per-node activation ranges during
``GraphPlan.warmup(calibrate=...)``, ``QuantPolicy`` decides which conv
nodes quantize (per-channel symmetric weight scales, per-tensor
activation scales from calibration, first/last-layer fp fallback), and
the ``cuconv_int8`` executor runs int8 x int8 -> int32 accumulation
with fp32 requantization in the epilogue.

Attribute access is lazy (PEP 562) so ``quant.symmetric`` — the
scale/clip/round core ``dist/compress.py`` also rides — imports without
dragging the graph/executor stack in.
"""
from __future__ import annotations

_EXPORTS = {
    "CALIB_SCHEMA": "calibrate", "Calibrator": "calibrate",
    "calibration_entry": "calibrate", "clear_cache": "calibrate",
    "graph_key": "calibrate",
    "NodeQuant": "policy", "QuantInfo": "policy",
    "QuantPolicy": "policy", "quantize_graph": "policy",
    "accuracy_report": "accuracy", "assert_accuracy": "accuracy",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.quant' has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f"repro.quant.{mod}"), name)
