"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, peak_lr=3e-4, warmup_steps=100,
                    total_steps=10_000, min_ratio=0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * jnp.minimum(1.0, (step + 1) / max(1, warmup_steps))
    prog = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps),
                    0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup_steps, warm, peak_lr * cos)
