"""AdamW in pure JAX with f32 master weights (mixed-precision training).

Model params live in bf16; the optimizer carries f32 master weights plus
f32 first/second moments (12 bytes/param), all sharded with the same
PartitionSpecs as the corresponding parameter (ZeRO-style: FSDP axis x TP
axis -> full 2D sharding of optimizer state).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


def adamw_init(params) -> Dict[str, Any]:
    # copy=True: an f32 param leaf's .astype(f32) would alias the SAME
    # buffer, and donating params+opt together then aborts with
    # "donate the same buffer twice"
    f32 = lambda t: jax.tree.map(
        lambda a: jnp.array(a, dtype=jnp.float32, copy=True), t)
    zeros = lambda t: jax.tree.map(
        lambda a: jnp.zeros(a.shape, jnp.float32), t)
    return {"master": f32(params), "m": zeros(params), "v": zeros(params)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(a.astype(jnp.float32)))
                        for a in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt, step, lr, *, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    """Returns (new_params, new_opt, metrics).  step is 0-based."""
    gnorm = global_norm(grads)
    scale = jnp.where(grad_clip > 0,
                      jnp.minimum(1.0, grad_clip / (gnorm + 1e-9)), 1.0)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, mw, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        step_vec = mhat / (jnp.sqrt(vhat) + eps)
        # decoupled weight decay on non-1D params (norms/biases excluded)
        if mw.ndim > 1:
            step_vec = step_vec + weight_decay * mw
        mw = mw - lr * step_vec
        return mw.astype(p.dtype), mw, m, v

    out = jax.tree.map(upd, params, grads, opt["master"], opt["m"], opt["v"])
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda o: isinstance(o, tuple))
    new_opt = {
        "master": jax.tree.map(lambda o: o[1], out,
                               is_leaf=lambda o: isinstance(o, tuple)),
        "m": jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda o: isinstance(o, tuple)),
        "v": jax.tree.map(lambda o: o[3], out,
                          is_leaf=lambda o: isinstance(o, tuple)),
    }
    return new_params, new_opt, {"grad_norm": gnorm}
