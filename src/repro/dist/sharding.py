"""Logical-axis sharding rules for every architecture's param pytree.

The layer stack (nn/*) is framework-free: params are plain dict pytrees.
Sharding metadata is attached here by *path pattern* — each leaf path is
matched against `_AXIS_TABLE` to get a tuple of logical axis names for
its trailing dims (any extra leading dims are the stacked-layer axis),
and `make_rules` maps logical names onto mesh axes per execution mode:

  embed (d_model)  -> 'data'   FSDP: gathered around each matmul
  heads/ff/vocab   -> 'model'  tensor parallel
  experts          -> 'model'  expert parallel (the bank's E axis)
  moe_ff / latent  -> None     already covered by EP / too small to cut
  batch            -> 'data' (or ('pod','data') across pods)

Big matrices therefore get BOTH an FSDP and a TP axis, e.g.
``attn/wq/w -> P(None, 'data', 'model')`` — the 2-D sharding the
dry-run's collective model assumes.

The serving subsystem (serve/distributed.py) uses the data-parallel
helpers at the bottom instead: CNN inference params are replicated
wholesale (``replicate_params``) and request batches shard their
leading axis (``batch_sharded``) over the 1-D serve mesh — no logical
axes needed.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: F401

# ---------------------------------------------------------------------------
# path -> logical axes for the trailing dims (first match wins)

_AXIS_TABLE = [
    # embeddings / head (lm_head has no bias in any current arch)
    (r"embed/embedding$",            ("vocab", "embed")),
    (r"lm_head/w$",                  ("embed", "vocab")),
    # any norm scale (ln1/ln2/q_norm/k_norm/kv_norm/final_norm/ssm norm)
    (r"scale$",                      ("null",)),
    # attention (GQA + MLA; only the qkv projections carry biases)
    (r"attn/w[qkv]/w$",              ("embed", "heads")),
    (r"attn/w[qkv]/b$",              ("heads",)),
    (r"attn/wo/w$",                  ("heads", "embed")),
    (r"attn/w_dkv/w$",               ("embed", "latent")),
    (r"attn/w_ukv/w$",               ("latent", "heads")),
    # MoE (experts bank leaves are raw (E, a, b) arrays)
    (r"router/w$",                   ("embed", "latent")),
    (r"experts/w[ig]$",              ("experts", "embed", "moe_ff")),
    (r"experts/wo$",                 ("experts", "moe_ff", "embed")),
    # dense / shared-expert SwiGLU MLP (bias-free in every current arch)
    (r"(mlp|shared)/w[ig]/w$",       ("embed", "ff")),
    (r"(mlp|shared)/wo/w$",          ("ff", "embed")),
    # mamba mixer (in-projections and out_proj are bias-free; the
    # depthwise conv taps keep theirs)
    (r"ssm/w(z|x|B|C|dt)/w$",        ("embed", "inner")),
    (r"ssm/conv_[xBC]/w$",           ("null", "inner")),
    (r"ssm/conv_[xBC]/b$",           ("inner",)),
    (r"ssm/(A_log|D|dt_bias)$",      ("null",)),
    (r"ssm/out_proj/w$",             ("inner", "embed")),
]
_AXIS_TABLE = [(re.compile(pat), ax) for pat, ax in _AXIS_TABLE]


def _path_str(path) -> str:
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(parts)


def logical_axes(tree) -> Any:
    """Map every param leaf to a tuple of logical axis names (same tree
    structure).  Raises KeyError on any unmatched path — the coverage
    guarantee test_sharding relies on."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        p = _path_str(path)
        for pat, trailing in _AXIS_TABLE:
            if pat.search(p):
                extra = leaf.ndim - len(trailing)
                if extra < 0:
                    raise KeyError(f"{p}: rank {leaf.ndim} < {trailing}")
                out.append(("layers",) * extra + tuple(trailing))
                break
        else:
            raise KeyError(f"no sharding rule matches param path {p!r}")
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# logical name -> mesh axes per mode

def make_rules(mode: str, multi_pod: bool = False,
               long_context: bool = False) -> Dict[str, Optional[Tuple]]:
    rules: Dict[str, Optional[Tuple]] = {
        "batch": ("pod", "data") if multi_pod else ("data",),
        "seq": None,
        "kv_len": None,
        "layers": None,
        "null": None,
        "embed": ("data",),       # FSDP
        "heads": ("model",),      # TP
        "ff": ("model",),
        "inner": ("model",),
        "vocab": ("model",),
        "experts": ("model",),    # EP
        "moe_ff": None,
        "latent": None,
    }
    if mode == "decode" and long_context:
        # sequence parallelism: the KV length axis takes the data axis,
        # batch (typically 1) is replicated
        rules["batch"] = None
        rules["kv_len"] = ("data",)
    return rules


def _entry(mesh_axes):
    """Rules store mesh axes as tuples; PartitionSpec equality is not
    tuple-insensitive (P('data') != P(('data',))), so unwrap singletons."""
    if mesh_axes is None:
        return None
    if isinstance(mesh_axes, tuple) and len(mesh_axes) == 1:
        return mesh_axes[0]
    return mesh_axes


def _spec_of(axis_names, rules) -> P:
    return P(*[_entry(rules.get(a)) for a in axis_names])


def param_specs(shapes, rules) -> Any:
    """PartitionSpec tree for a param (shape) tree under the given rules."""
    axes = logical_axes(shapes)
    return jax.tree.map(lambda ax: _spec_of(ax, rules), axes,
                        is_leaf=lambda a: isinstance(a, tuple))


def opt_specs(pspecs) -> Dict[str, Any]:
    """AdamW state mirrors params three ways (master/m/v)."""
    return {"master": pspecs, "m": pspecs, "v": pspecs}


def batch_specs(batch_shapes: Dict[str, Any], rules) -> Dict[str, Any]:
    """Input-batch specs: batch axis sharded, everything else replicated.
    positions may be (3, B, S) for M-RoPE — batch axis is dim 1 there."""
    b = _entry(rules["batch"])
    out = {}
    for k, v in batch_shapes.items():
        if k == "positions" and v.ndim == 3:
            out[k] = P(None, b, None)
        else:
            out[k] = P(b, *([None] * (v.ndim - 1)))
    return out


def cache_specs(cache_shapes, cfg, rules) -> Any:
    """Decode-cache specs: (layers, batch, length, ...) leaves.  Dim 2
    of rank>=4 leaves takes the kv_len rule so long-context decode can
    sequence-shard KV caches; for SSM conv/state caches that axis is
    tiny (d_conv-1 / heads) and kv_len is None outside long-context
    mode, so the approximation only costs GSPMD padding in the
    long-context dry-run estimates."""
    b, kl = _entry(rules["batch"]), _entry(rules.get("kv_len"))

    def spec(leaf):
        if leaf.ndim >= 4:        # (layers, batch, length, heads...) caches
            return P(None, b, kl, *([None] * (leaf.ndim - 3)))
        if leaf.ndim >= 2:        # (layers, batch, ...) conv/ssm states
            return P(None, b, *([None] * (leaf.ndim - 2)))
        return P(*([None] * leaf.ndim))

    return jax.tree.map(spec, cache_shapes,
                        is_leaf=lambda s: hasattr(s, "ndim"))


def named(mesh, tree) -> Any:
    """PartitionSpec tree -> NamedSharding tree on the given mesh."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# data-parallel serving (serve/distributed.py): CNN param trees carry no
# logical axes — inference params are replicated wholesale and only the
# batch axis of each request batch is cut over the serve mesh.

def replicated(mesh) -> NamedSharding:
    """Fully-replicated sharding on ``mesh`` (every leaf on every device)."""
    return NamedSharding(mesh, P())


def batch_sharded(mesh, ndim: int, axis: str = "data") -> NamedSharding:
    """Leading (batch) dim sharded over ``axis``, all others replicated."""
    if ndim < 1:
        raise ValueError(f"batch_sharded needs rank >= 1; got {ndim}")
    return NamedSharding(mesh, P(axis, *([None] * (ndim - 1))))


def is_replicated_on(leaf, mesh) -> bool:
    """True when ``leaf`` is already a device array fully replicated
    across exactly ``mesh``'s devices (so ``device_put`` would be a
    re-transfer, not a placement)."""
    sh = getattr(leaf, "sharding", None)
    if sh is None or not sh.is_fully_replicated:
        return False
    return set(getattr(leaf, "devices", lambda: ())()) == set(
        mesh.devices.flat)


def replicate_params(params, mesh):
    """Replicate an inference param tree onto ``mesh`` ONCE.

    Leaves already replicated on this mesh pass through untouched, so
    layers sharing one param tree (a dispatcher handing the same tree
    to several geometries' bucket programs) trigger exactly one
    host→device transfer however many times this is called.  Everything
    downstream passes the returned tree by reference; serving never
    re-transfers it (``jax.transfer_guard("disallow")``-clean).
    """
    target = replicated(mesh)
    return jax.tree.map(
        lambda leaf: leaf if is_replicated_on(leaf, mesh)
        else jax.device_put(leaf, target), params)
