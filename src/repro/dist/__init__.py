"""Distributed execution utilities: sharding rules and gradient compression."""
