"""Int8 gradient compression with error feedback.

Symmetric per-block int8: each flattened 256-element block is scaled by
max|block|/127, so the worst-case per-element error is scale/2 <=
max|block|/254.  Error feedback carries the quantization residual into
the next step, so the *sum* of compressed gradients tracks the true sum
to within one quantization step (test_checkpoint asserts both bounds).

The int8 payload (q, per-block scales) is what a cross-pod DCN
transport would move; `compressed_psum` models that all-reduce inside
shard_map.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.quant import symmetric

BLOCK = 256


def quantize(x, block: int = BLOCK):
    """x: float array -> (q int8, scales (nblocks, 1) f32, orig shape)."""
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = symmetric.scale_for(
        symmetric.abs_max(blocks, axis=1, keepdims=True))
    q = symmetric.quantize_to_int8(blocks, scale)
    return q, scale, shape


def dequantize(q, scale, shape):
    flat = symmetric.dequantize_int8(q, scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def quantize_with_feedback(g, err) -> Tuple[Tuple, Any]:
    """Compress (g + err); the new residual is what compression lost."""
    target = g.astype(jnp.float32) + err
    q, s, shape = quantize(target)
    new_err = target - dequantize(q, s, shape)
    return (q, s, shape), new_err


def init_feedback(params):
    return jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), params)


def tree_quantize_with_feedback(grads, ef):
    """Per-leaf EF compression; returns (dequantized grads, new ef tree).
    The dequantized values are what the optimizer consumes — the int8
    payload is the wire format."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    deqs, errs = [], []
    for g, e in zip(flat_g, flat_e):
        (q, s, shape), ne = quantize_with_feedback(g, e)
        deqs.append(dequantize(q, s, shape))
        errs.append(ne)
    return (jax.tree_util.tree_unflatten(treedef, deqs),
            jax.tree_util.tree_unflatten(treedef, errs))


def compressed_psum(x, axis_name: str, err):
    """EF-compressed all-reduce over `axis_name` (inside shard_map):
    each participant contributes its dequantized int8 payload."""
    (q, s, shape), new_err = quantize_with_feedback(x, err)
    out = jax.lax.psum(dequantize(q, s, shape), axis_name)
    return out, new_err
