from repro.data.pipeline import SyntheticLMData, FileLMData  # noqa: F401
