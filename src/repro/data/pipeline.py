"""Deterministic, resumable data pipelines.

Fault-tolerance contract: a batch is a pure function of (seed, step), so
restart-from-checkpoint needs no pipeline state beyond the step counter —
the standard trick large training jobs use to make the input pipeline
trivially elastic (any host can compute any shard of any step).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


class SyntheticLMData:
    """Markov-chain token stream: learnable structure (loss goes well below
    the uniform-entropy floor) while remaining fully synthetic."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, order_bias: float = 0.8):
        self.vocab, self.batch, self.seq = vocab_size, batch, seq_len
        self.seed = seed
        rng = np.random.default_rng(seed)
        # sparse "grammar": each token strongly prefers a few successors
        self.succ = rng.integers(0, vocab_size, size=(vocab_size, 4))
        self.order_bias = order_bias

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        toks = np.empty((self.batch, self.seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, self.batch)
        follow = rng.random((self.batch, self.seq)) < self.order_bias
        choice = rng.integers(0, 4, (self.batch, self.seq))
        rand = rng.integers(0, self.vocab, (self.batch, self.seq))
        for t in range(self.seq):
            nxt = self.succ[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], nxt, rand[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class FileLMData:
    """Flat binary token file (uint16/uint32), sharded by step index."""

    def __init__(self, path: str, vocab_size: int, batch: int, seq_len: int,
                 dtype=np.uint16):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.vocab, self.batch, self.seq = vocab_size, batch, seq_len
        self.tokens_per_batch = batch * (seq_len + 1)
        self.num_batches = len(self.data) // self.tokens_per_batch

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        i = (step % self.num_batches) * self.tokens_per_batch
        chunk = np.asarray(self.data[i:i + self.tokens_per_batch],
                           dtype=np.int32)
        chunk = chunk.reshape(self.batch, self.seq + 1) % self.vocab
        return {"tokens": chunk[:, :-1], "labels": chunk[:, 1:].copy()}
