"""Serving front-end configuration: geometries, batching policy, SLOs.

One frozen dataclass describes an ``AsyncServeFrontend`` deployment —
which ``(image_shape, buckets)`` programs it owns, how long a short
batch may wait before dispatching padded, the default latency SLO, and
the dispatch pipeline depth.  The CI smoke step and
``benchmarks/graph_serve.py`` both build their frontends from the
configs here so "the benchmarked deployment" is one named object, not
numbers scattered across call sites.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

#: The generous default SLO (milliseconds) used by smoke/benchmark
#: traffic: wide enough that a CPU-backed interpret-mode run never
#: misses it — CI asserts ZERO deadline misses at this value — while
#: still exercising the deadline-accounting path for every request.
DEFAULT_SLO_MS = 60_000.0


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """One async-serving deployment.

    ``geometries`` maps each served image shape to its bucket tuple;
    ``max_wait_ms`` is the batch-close patience for short batches;
    ``default_deadline_ms`` is the SLO applied to requests that carry
    no explicit ``deadline_ms`` (None = no implicit deadline);
    ``pipeline_depth`` bounds how many dispatched batches may be in
    flight before the scheduler harvests (2 = double buffering).
    """
    geometries: Tuple[Tuple[Tuple[int, int, int], Tuple[int, ...]], ...]
    max_wait_ms: float = 2.0
    default_deadline_ms: Optional[float] = DEFAULT_SLO_MS
    pipeline_depth: int = 2

    def geometry_map(self):
        return {tuple(shape): tuple(buckets)
                for shape, buckets in self.geometries}


#: the deployment the CI async-serve smoke and the benchmark serve:
#: resnet_like traffic at two image resolutions through ONE frontend
SMOKE_FRONTEND = FrontendConfig(
    geometries=(((32, 32, 3), (1, 4)),
                ((16, 16, 3), (1, 2))),
    max_wait_ms=5.0,
    default_deadline_ms=DEFAULT_SLO_MS,
    pipeline_depth=2,
)


#: the multi-device smoke deployment, shared by the CI
#: multi-device-smoke step, benchmarks/loadgen.py's ``sharded_scaling``
#: sweep, and tests/test_distributed_serve.py.  The model is the named
#: ``models.cnn.tiny_cnn``.  Buckets here are PER-SHARD capacities — a
#: ``ShardedServeDispatcher`` on an N-device mesh serves global buckets
#: N× these — and each geometry carries a SINGLE bucket so every image
#: flows through one per-shard batch-shape program, the precondition
#: for bitwise-identical outputs across device counts.
DIST_SMOKE = FrontendConfig(
    geometries=(((8, 8, 3), (2,)),
                ((12, 12, 3), (2,))),
    max_wait_ms=2.0,
    default_deadline_ms=DEFAULT_SLO_MS,
    pipeline_depth=2,
)
