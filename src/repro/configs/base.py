"""Model / run configuration system.

Every assigned architecture is a ``ModelConfig`` registered under its id
(``--arch <id>``).  Reduced "smoke" variants (same family, tiny dims) are
derived via :func:`smoke_variant` and used by CPU tests; the full configs
are only ever lowered via the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Layer kinds used to describe hybrid stacking patterns.
ATTN = "attn"   # self-attention (GQA / MHA / MLA)
SSM = "ssm"     # Mamba2 SSD block
DENSE = "dense" # dense MLP
MOE = "moe"     # routed mixture-of-experts MLP


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # --- attention flavour -------------------------------------------------
    qkv_bias: bool = False          # qwen2 family
    qk_norm: bool = False           # qwen3
    rope_theta: float = 1_000_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w) dims
    # MLA (deepseek-v2)
    mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0            # routed experts
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0               # per-expert FFN width
    moe_every: int = 1              # a MoE MLP every k layers (others dense)
    first_layer_dense: bool = False # deepseek-moe: layer 0 keeps dense MLP
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    d_conv: int = 4

    # --- hybrid stacking ----------------------------------------------------
    # Repeating pattern of layer kinds.  () means uniform (ATTN or SSM based
    # on family).  jamba: 8-layer period, attention at index 4, MoE every 2.
    layer_pattern: Tuple[str, ...] = ()

    # --- input modality -----------------------------------------------------
    # "tokens": int32 token ids.  "embeddings": the modality frontend is a
    # stub and the model consumes precomputed frame/patch embeddings.
    input_mode: str = "tokens"
    tie_embeddings: bool = False

    # --- norm ---------------------------------------------------------------
    rms_norm_eps: float = 1e-5

    # --- training-time knobs (overridable per run) ---------------------------
    grad_accum: int = 1             # microbatch accumulation steps
    remat: str = "full"             # "none" | "full" (recompute layer interior)

    # --- execution-structure knobs (cost probes / perf experiments) ----------
    scan_layers: bool = True        # lax.scan over layers (False: unrolled)
    attn_impl: str = "auto"         # "auto" | "chunked_unrolled" | "exact"
    ce_impl: str = "simple"         # "simple" | "chunked" (§Perf lever: the
                                    # simple path materializes f32 logits)
    attn_score_dtype: str = "f32"   # "f32" | "bf16" (§Perf: halves the
                                    # chunked-attention score/prob HBM traffic)
    shard_heads: str = "none"       # "none" | "head_dim": pin q/k/v
                                    # (B,S,H,hd) sharding (hd over 'model');
                                    # rescues archs with heads % TP != 0
    ssm_chunk: int = 256            # SSD chunk length (§Perf: diag-block
                                    # traffic scales linearly with it)
    norm_impl: str = "f32"          # "f32" | "stat_f32": keep the variance
                                    # reduction in f32 but normalize in bf16
                                    # (§Perf: kills (B,S,D)-sized f32 traffic)
    rope_impl: str = "f32"          # "f32" | "bf16": rotate in bf16

    # -------------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def conv_dim(self) -> int:
        # mamba2: conv runs over x (d_inner) plus B and C streams
        return self.d_inner + 2 * self.ssm_groups * self.ssm_state

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so TP sharding divides evenly (multiple of 256)."""
        return ((self.vocab_size + 255) // 256) * 256

    def layer_kinds(self) -> Tuple[Tuple[str, str], ...]:
        """Full per-layer (mixer_kind, mlp_kind) schedule of the stack."""
        out = []
        for i in range(self.num_layers):
            if self.layer_pattern:
                mixer = self.layer_pattern[i % len(self.layer_pattern)]
            else:
                mixer = SSM if self.family == "ssm" else ATTN
            if self.num_experts > 0 and (i % self.moe_every == self.moe_every - 1
                                         or self.moe_every == 1):
                mlp = MOE
            else:
                mlp = DENSE
            if self.first_layer_dense and i == 0:
                mlp = DENSE
            if self.family == "ssm":
                mlp = "none"        # mamba2 blocks have no separate MLP
            out.append((mixer, mlp))
        return tuple(out)

    @property
    def uniform_stack(self) -> bool:
        """True when every layer is identical -> scan over all layers."""
        kinds = self.layer_kinds()
        return all(k == kinds[0] for k in kinds)

    @property
    def pattern_period(self) -> int:
        """Length of the repeating layer pattern (scan unit)."""
        if self.uniform_stack:
            return 1
        # honour both the mixer pattern and the moe_every cadence
        period = len(self.layer_pattern) if self.layer_pattern else 1
        if self.num_experts > 0 and self.moe_every > 1:
            import math
            period = math.lcm(period, self.moe_every)
        # first_layer_dense breaks periodicity; fall back to unrolled
        if self.first_layer_dense:
            return 0
        if self.num_layers % period != 0:
            return 0                # 0 => no clean period, unroll
        return period

    def num_params(self) -> int:
        """Analytic parameter count (for 6ND model-flops accounting)."""
        p = 0
        V, D = self.padded_vocab, self.d_model
        if self.input_mode == "tokens":
            p += V * D                                 # embed
        if not self.tie_embeddings:
            p += D * V                                 # lm head
        p += D                                         # final norm
        for mixer, mlp in self.layer_kinds():
            p += D if mlp == "none" else 2 * D         # pre-norms
            if mixer == ATTN:
                if self.mla:
                    qk_dim = self.qk_nope_dim + self.qk_rope_dim
                    p += D * self.num_heads * qk_dim                   # wq
                    p += D * (self.kv_lora_rank + self.qk_rope_dim)    # w_dkv
                    p += self.kv_lora_rank * self.num_heads * (
                        self.qk_nope_dim + self.v_head_dim)            # w_ukv
                    p += self.num_heads * self.v_head_dim * D          # wo
                else:
                    p += D * self.q_dim + 2 * D * self.kv_dim
                    p += self.q_dim * D
                    if self.qkv_bias:
                        p += self.q_dim + 2 * self.kv_dim
            elif mixer == SSM:
                d_in, conv = self.d_inner, self.conv_dim
                p += D * (2 * d_in + 2 * self.ssm_groups * self.ssm_state
                          + self.ssm_heads)            # z/x/B/C/dt projs
                p += self.d_conv * conv + conv         # conv1d w + bias
                p += 3 * self.ssm_heads                # A_log, D, dt_bias
                p += d_in                              # gated norm
                p += d_in * D                          # out_proj
            if mlp == DENSE:
                p += 3 * D * self.d_ff
            elif mlp == MOE:
                p += D * self.num_experts              # router
                p += self.num_experts * 3 * D * self.moe_d_ff
                p += self.num_shared_experts * 3 * D * self.moe_d_ff
        return p

    def num_active_params(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if self.num_experts == 0:
            return self.num_params()
        p = self.num_params()
        for mixer, mlp in self.layer_kinds():
            if mlp == MOE:
                inactive = self.num_experts - self.experts_per_token
                p -= inactive * 3 * self.d_model * self.moe_d_ff
        return p


# ---------------------------------------------------------------------------
# Input shape cells (the assigned shape set for the LM family).

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    from repro import configs as _pkg  # ensure config modules imported
    _pkg.load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs():
    from repro import configs as _pkg
    _pkg.load_all()
    return sorted(_REGISTRY)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config that runs a real step on CPU."""
    n_layers = max(2, len(cfg.layer_pattern)) if cfg.layer_pattern else 2
    if cfg.num_experts > 0 and cfg.moe_every > 1:
        import math
        n_layers = math.lcm(n_layers, cfg.moe_every)
    if cfg.first_layer_dense:
        n_layers = max(n_layers, 2)
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=n_layers,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )
    if cfg.num_experts:
        kw.update(num_experts=4, experts_per_token=min(2, cfg.experts_per_token),
                  moe_d_ff=32)
    if cfg.mla:
        kw.update(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16)
    if cfg.mrope_sections:
        kw.update(mrope_sections=(4, 2, 2))
    return dataclasses.replace(cfg, **kw)
