"""Forward-propagation convolution configurations from the paper's five CNNs.

The paper (§4, Table 1) draws >600 (config x batch) cells from AlexNet,
GoogleNet, ResNet-50, SqueezeNet and VGG19 — all stride 1, padding
(K-1)/2, square inputs/filters, fp32.  The exact per-layer list lives in
the authors' earlier study [11] which is not in the text, so the lists
below are reconstructed from the public architecture definitions; the
distinct-config counts and filter-size fractions match Table 1 (GoogleNet
within a few configs of the published 42 — noted in EXPERIMENTS.md).

Entries are ``(input_hw, k, num_filters_M, depth_C)`` mirroring the
paper's ``[input size]-[#filters]-[depth]`` labels.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

Conv = Tuple[int, int, int, int]          # (H=W, K, M, C)

BATCH_SIZES = (1, 8, 16, 32, 64, 128, 256)

# AlexNet (original Krizhevsky counts; conv1 11x11/4 excluded: stride 4)
ALEXNET: List[Conv] = [
    (27, 5, 256, 96),
    (13, 3, 384, 256),
    (13, 3, 384, 384),
    (13, 3, 256, 384),
]

# VGG19 (all 3x3 stride 1)
VGG19: List[Conv] = [
    (224, 3, 64, 3), (224, 3, 64, 64),
    (112, 3, 128, 64), (112, 3, 128, 128),
    (56, 3, 256, 128), (56, 3, 256, 256),
    (28, 3, 512, 256), (28, 3, 512, 512),
    (14, 3, 512, 512),
]

# SqueezeNet 1.0 fire modules (squeeze/expand) + conv10
SQUEEZENET: List[Conv] = [
    (55, 1, 16, 96), (55, 1, 64, 16), (55, 3, 64, 16),
    (55, 1, 16, 128),
    (55, 1, 32, 128), (55, 1, 128, 32), (55, 3, 128, 32),
    (27, 1, 32, 256), (27, 1, 128, 32), (27, 3, 128, 32),
    (27, 1, 48, 256), (27, 1, 192, 48), (27, 3, 192, 48),
    (27, 1, 48, 384),
    (27, 1, 64, 384), (27, 1, 256, 64), (27, 3, 256, 64),
    (13, 1, 64, 512), (13, 1, 256, 64), (13, 3, 256, 64),
    (13, 1, 1000, 512),
]

# ResNet-50 stride-1 convs (downsample/stride-2 convs excluded)
RESNET50: List[Conv] = [
    (56, 3, 64, 64), (56, 1, 256, 64), (56, 1, 64, 256),
    (28, 3, 128, 128), (28, 1, 512, 128), (28, 1, 128, 512),
    (14, 3, 256, 256), (14, 1, 1024, 256), (14, 1, 256, 1024),
    (7, 3, 512, 512), (7, 1, 2048, 512), (7, 1, 512, 2048),
]

# GoogLeNet: conv2/conv3 + the nine inception modules
# per module: 1x1 branch, 3x3 reduce, 3x3, 5x5 reduce, 5x5, pool proj
_INCEPTION = [
    # (hw, C_in, n1, r3, n3, r5, n5, pp)
    (28, 192, 64, 96, 128, 16, 32, 32),
    (28, 256, 128, 128, 192, 32, 96, 64),
    (14, 480, 192, 96, 208, 16, 48, 64),
    (14, 512, 160, 112, 224, 24, 64, 64),
    (14, 512, 128, 128, 256, 24, 64, 64),
    (14, 512, 112, 144, 288, 32, 64, 64),
    (14, 528, 256, 160, 320, 32, 128, 128),
    (7, 832, 256, 160, 320, 32, 128, 128),
    (7, 832, 384, 192, 384, 48, 128, 128),
]


def _googlenet() -> List[Conv]:
    out: List[Conv] = [(56, 1, 64, 64), (56, 3, 192, 64)]
    for hw, cin, n1, r3, n3, r5, n5, pp in _INCEPTION:
        out += [
            (hw, 1, n1, cin), (hw, 1, r3, cin), (hw, 3, n3, r3),
            (hw, 1, r5, cin), (hw, 5, n5, r5), (hw, 1, pp, cin),
        ]
    # distinct configs only (paper counts distinct parameterizations)
    seen, ded = set(), []
    for c in out:
        if c not in seen:
            seen.add(c)
            ded.append(c)
    return ded


GOOGLENET: List[Conv] = _googlenet()

NETWORKS: Dict[str, List[Conv]] = {
    "googlenet": GOOGLENET,
    "squeezenet": SQUEEZENET,
    "alexnet": ALEXNET,
    "resnet50": RESNET50,
    "vgg19": VGG19,
}

# Depthwise stages of MobileNet v1 (3x3, groups == C): outside the
# paper's five networks — the paper has no grouped convs at all — but
# the operator IR plans them end-to-end via feature_group_count, so the
# benchmark/test surface names real configurations here.
GroupedConv = Tuple[int, int, int, int, int]   # (H=W, K, M, C, groups)

MOBILENET_DW: List[GroupedConv] = [
    (112, 3, 32, 32, 32),
    (56, 3, 64, 64, 64),
    (28, 3, 128, 128, 128),
    (14, 3, 256, 256, 256),
    (7, 3, 512, 512, 512),
]

# configurations profiled in the paper's tables 3-5
# label -> (hw, batch, k, M, C)
PROFILED = {
    "t3_A": (7, 1, 1, 256, 832),     # table 3 A (cuConv 2.29x region)
    "t3_B": (14, 1, 1, 1024, 256),   # table 3 B
    "t3_C": (27, 1, 1, 256, 64),     # table 3 C
    "t4_A": (7, 1, 3, 384, 192),     # table 4 A
    "t4_B": (13, 1, 3, 384, 384),    # table 4 B
    "t5_A": (7, 1, 5, 128, 48),      # table 5 A
    "t5_B": (7, 8, 5, 128, 48),      # table 5 B
}


def all_distinct() -> List[Conv]:
    seen, out = set(), []
    for net in NETWORKS.values():
        for c in net:
            if c not in seen:
                seen.add(c)
                out.append(c)
    return out


def filter_size_fractions(net: str) -> Dict[int, float]:
    convs = NETWORKS[net]
    out: Dict[int, float] = {}
    for _, k, _, _ in convs:
        out[k] = out.get(k, 0) + 1
    return {k: v / len(convs) for k, v in sorted(out.items())}
