from repro.configs import base  # noqa: F401
from repro.configs.base import get_config, list_archs, smoke_variant, SHAPES  # noqa: F401

_LOADED = False

def load_all():
    global _LOADED
    if _LOADED:
        return
    from repro.configs import archs, cnn_paper  # noqa: F401
    _LOADED = True
