"""The 10 assigned architectures (exact public configs; see brackets).

Each is selectable via ``--arch <id>`` in the launchers and dry-run.
"""
from __future__ import annotations

from repro.configs.base import ATTN, SSM, ModelConfig, register


@register("qwen2-72b")
def qwen2_72b():
    # [arXiv:2407.10671; hf] GQA kv=8, QKV bias
    return ModelConfig(
        name="qwen2-72b", family="dense", num_layers=80, d_model=8192,
        num_heads=64, num_kv_heads=8, head_dim=128, d_ff=29568,
        vocab_size=152064, qkv_bias=True, rope_theta=1e6, grad_accum=16)


@register("mistral-large-123b")
def mistral_large_123b():
    # [hf:mistralai/Mistral-Large-Instruct-2407; unverified]
    return ModelConfig(
        name="mistral-large-123b", family="dense", num_layers=88,
        d_model=12288, num_heads=96, num_kv_heads=8, head_dim=128,
        d_ff=28672, vocab_size=32768, rope_theta=1e6, grad_accum=16)


@register("qwen2-1.5b")
def qwen2_1_5b():
    # [arXiv:2407.10671; hf] GQA kv=2, QKV bias
    return ModelConfig(
        name="qwen2-1.5b", family="dense", num_layers=28, d_model=1536,
        num_heads=12, num_kv_heads=2, head_dim=128, d_ff=8960,
        vocab_size=151936, qkv_bias=True, rope_theta=1e6,
        tie_embeddings=True, grad_accum=4)


@register("qwen3-14b")
def qwen3_14b():
    # [hf:Qwen/Qwen3-8B; hf] qk_norm, GQA kv=8
    return ModelConfig(
        name="qwen3-14b", family="dense", num_layers=40, d_model=5120,
        num_heads=40, num_kv_heads=8, head_dim=128, d_ff=17408,
        vocab_size=151936, qk_norm=True, rope_theta=1e6, grad_accum=8)


@register("jamba-v0.1-52b")
def jamba_52b():
    # [arXiv:2403.19887; hf] Mamba+attn 1:7 interleave, MoE 16e top-2
    # 8-layer period with attention at index 4; MoE every 2nd layer.
    pattern = (SSM, SSM, SSM, SSM, ATTN, SSM, SSM, SSM)
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid", num_layers=32, d_model=4096,
        num_heads=32, num_kv_heads=8, head_dim=128, d_ff=14336,
        vocab_size=65536, layer_pattern=pattern,
        num_experts=16, experts_per_token=2, moe_d_ff=14336, moe_every=2,
        ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
        d_conv=4, rope_theta=1e6, grad_accum=8)


@register("musicgen-large")
def musicgen_large():
    # [arXiv:2306.05284; hf] decoder-only over EnCodec tokens (frontend stub)
    return ModelConfig(
        name="musicgen-large", family="audio", num_layers=48, d_model=2048,
        num_heads=32, num_kv_heads=32, head_dim=64, d_ff=8192,
        vocab_size=2048, input_mode="embeddings", rope_theta=1e4,
        grad_accum=4)


@register("deepseek-v2-lite-16b")
def deepseek_v2_lite():
    # [arXiv:2405.04434; hf] MLA kv_lora=512, 2 shared + 64 routed top-6
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe", num_layers=27,
        d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
        d_ff=10944, vocab_size=102400,
        mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
        v_head_dim=128,
        num_experts=64, num_shared_experts=2, experts_per_token=6,
        moe_d_ff=1408, moe_every=1, first_layer_dense=True,
        rope_theta=1e4, grad_accum=4)


@register("deepseek-moe-16b")
def deepseek_moe_16b():
    # [arXiv:2401.06066; hf] 2 shared + 64 routed top-6, fine-grained
    return ModelConfig(
        name="deepseek-moe-16b", family="moe", num_layers=28, d_model=2048,
        num_heads=16, num_kv_heads=16, head_dim=128, d_ff=10944,
        vocab_size=102400,
        num_experts=64, num_shared_experts=2, experts_per_token=6,
        moe_d_ff=1408, moe_every=1, first_layer_dense=True,
        rope_theta=1e4, grad_accum=4)


@register("qwen2-vl-2b")
def qwen2_vl_2b():
    # [arXiv:2409.12191; hf] M-RoPE (t,h,w) sections; patch frontend stub
    return ModelConfig(
        name="qwen2-vl-2b", family="vlm", num_layers=28, d_model=1536,
        num_heads=12, num_kv_heads=2, head_dim=128, d_ff=8960,
        vocab_size=151936, qkv_bias=True, input_mode="embeddings",
        mrope_sections=(16, 24, 24), rope_theta=1e6, grad_accum=4)


@register("mamba2-1.3b")
def mamba2_1_3b():
    # [arXiv:2405.21060; unverified] SSD, attn-free, ssm_state=128
    return ModelConfig(
        name="mamba2-1.3b", family="ssm", num_layers=48, d_model=2048,
        num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0,
        vocab_size=50280, ssm_state=128, ssm_expand=2, ssm_head_dim=64,
        ssm_groups=1, d_conv=4, tie_embeddings=True, grad_accum=4)
