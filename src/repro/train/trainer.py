"""Training loop: checkpoint/restart, straggler deadline, elastic re-mesh.

Single-host CI runs the same code a pod launcher would drive; the
fault-tolerance hooks are real (atomic checkpoints, auto-resume,
deadline-based step skip) and the multi-host-only parts (pod rejoin
barrier) are documented where they would attach.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dist import sharding as shd
from repro.launch import steps as St
from repro.models import lm
from repro.optim import adamw_init
from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    ckpt_async: bool = True
    peak_lr: float = 3e-4
    log_every: int = 10
    seed: int = 0
    # straggler mitigation: if a step exceeds deadline x median, log and
    # (on a real pod) trigger the rejoin protocol; here we record it.
    straggler_factor: float = 3.0
    grad_compression: bool = False     # int8 + error feedback (dist.compress)


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, data,
                 mesh=None, rules=None):
        self.cfg, self.tcfg, self.data = cfg, tcfg, data
        self.mesh = mesh
        self.metrics_log = []
        self._step_times = []

        act_spec = None
        state_shapes = St.state_specs(cfg)
        if mesh is not None:
            rules = rules or shd.make_rules("train", "pod" in mesh.axis_names)
            pspecs = shd.param_specs(state_shapes["params"], rules)
            self.sspecs = {"params": pspecs, "opt": shd.opt_specs(pspecs),
                           "step": shd.P()}
            act_spec = shd.named(mesh, shd.P(rules["batch"], None, None))
            shardings = shd.named(mesh, self.sspecs)
            bspecs = shd.named(
                mesh, shd.batch_specs(
                    jax.tree.map(lambda a: a, St.input_specs(
                        cfg, _train_shape(cfg, data))), rules))
            if tcfg.grad_compression:
                self.sspecs["ef"] = pspecs
                shardings = shd.named(mesh, self.sspecs)
            self.step_fn = jax.jit(
                St.make_train_step(cfg, peak_lr=tcfg.peak_lr,
                                   act_spec=act_spec,
                                   grad_compression=tcfg.grad_compression),
                in_shardings=(shardings, bspecs),
                out_shardings=(shardings, None),
                donate_argnums=(0,))
        else:
            self.sspecs = None
            self.step_fn = jax.jit(
                St.make_train_step(cfg, peak_lr=tcfg.peak_lr,
                                   grad_compression=tcfg.grad_compression),
                donate_argnums=(0,))

        self.state = None

    # ------------------------------------------------------------------
    def init_state(self):
        params = lm.init_lm(self.cfg, jax.random.PRNGKey(self.tcfg.seed))
        state = {"params": params, "opt": adamw_init(params),
                 "step": jnp.zeros((), jnp.int32)}
        if self.tcfg.grad_compression:
            from repro.dist import compress as C
            state["ef"] = C.init_feedback(params)
        if self.mesh is not None:
            state = jax.device_put(state, shd.named(self.mesh, self.sspecs))
        return state

    def resume_or_init(self):
        last = ckpt.latest_step(self.tcfg.ckpt_dir)
        like = jax.eval_shape(self.init_state)
        if last is not None:
            shardings = (shd.named(self.mesh, self.sspecs)
                         if self.mesh is not None else None)
            self.state = ckpt.restore_checkpoint(
                self.tcfg.ckpt_dir, last, like, shardings=shardings)
            print(f"[trainer] resumed from step {last}")
        else:
            self.state = self.init_state()
        return int(self.state["step"])

    # ------------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        start = self.resume_or_init()
        pending = None
        for step in range(start, self.tcfg.steps):
            batch = {k: jnp.asarray(v)
                     for k, v in self.data.batch_at(step).items()}
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            self._step_times.append(dt)
            med = float(np.median(self._step_times[-20:]))
            if dt > self.tcfg.straggler_factor * med and len(
                    self._step_times) > 5:
                metrics["straggler_detected"] = dt / med
            metrics["step"], metrics["step_time_s"] = step, dt
            self.metrics_log.append(metrics)
            if step % self.tcfg.log_every == 0:
                print(f"[trainer] step {step} loss {metrics['loss']:.4f} "
                      f"({dt*1e3:.0f} ms)")
            if (step + 1) % self.tcfg.ckpt_every == 0 or \
                    step + 1 == self.tcfg.steps:
                if pending is not None and hasattr(pending, "join"):
                    pending.join()                      # one in flight max
                pending = ckpt.save_checkpoint(
                    self.tcfg.ckpt_dir, step + 1, self.state,
                    async_=self.tcfg.ckpt_async)
        if pending is not None and hasattr(pending, "join"):
            pending.join()
        return self.metrics_log[-1] if self.metrics_log else {}


def _train_shape(cfg, data):
    from repro.configs.base import ShapeConfig
    return ShapeConfig("custom", data.seq, data.batch, "train")
