"""Fault-tolerant checkpointing.

Design (single-host CI twin of a multi-host production layout):
  * atomic: write to ``step_N.tmp/`` then rename to ``step_N/`` — a crash
    mid-write can never corrupt the latest checkpoint;
  * self-describing: ``manifest.json`` records the flattened tree paths,
    shapes, dtypes and a content hash per array — restore verifies
    integrity and refuses silently-truncated files;
  * mesh-elastic: arrays are saved UNSHARDED (gathered) with their
    PartitionSpec recorded; restore re-shards onto whatever mesh the new
    job brings up (tested: save on mesh A, restore on mesh B).  On a real
    multi-host pod each host would write its addressable shards and
    restore would assemble per-host — the manifest format already carries
    everything needed;
  * async: ``save_checkpoint(..., async_=True)`` hands the device->host
    copy result to a writer thread so the train loop never blocks on
    disk;
  * auto-resume: ``latest_step`` scans for the newest complete checkpoint.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

_SENTINEL = "manifest.json"

# numpy can't serialize bf16 & friends natively: store the raw bits in a
# same-width integer view and record the logical dtype in the manifest
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8)}


def _encode(v: np.ndarray):
    if v.dtype.name in _EXOTIC:
        return v.view(_EXOTIC[v.dtype.name][1]), v.dtype.name
    return v, str(v.dtype)


def _decode(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC:
        return arr.view(_EXOTIC[dtype_name][0])
    return arr


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = leaf
    return flat


def _tree_def(tree):
    return jax.tree_util.tree_structure(tree)


def _hash(arr: np.ndarray) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()[:16]


def save_checkpoint(ckpt_dir, step: int, tree, *, async_=False,
                    keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    # device -> host (blocking part; the disk write can be async)
    host = {k: np.asarray(v) for k, v in flat.items()}

    def write():
        tmp = ckpt_dir / f"step_{step}.tmp"
        final = ckpt_dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        manifest = {"step": step, "arrays": {}}
        for k, v in host.items():
            fname = hashlib.md5(k.encode()).hexdigest()[:12] + ".npy"
            enc, dtype_name = _encode(v)
            np.save(tmp / fname, enc)
            manifest["arrays"][k] = {
                "file": fname, "shape": list(v.shape), "dtype": dtype_name,
                "hash": _hash(enc),
            }
        (tmp / _SENTINEL).write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic publish
        _gc(ckpt_dir, keep)

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return ckpt_dir / f"step_{step}"


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)


def latest_steps(ckpt_dir) -> list:
    ckpt_dir = Path(ckpt_dir)
    out = []
    if not ckpt_dir.exists():
        return out
    for d in ckpt_dir.iterdir():
        if d.is_dir() and d.name.startswith("step_") and \
                not d.name.endswith(".tmp") and (d / _SENTINEL).exists():
            out.append(int(d.name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir) -> Optional[int]:
    steps = latest_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir, step: int, like_tree, *, shardings=None,
                       verify: bool = True):
    """Restore into the structure of ``like_tree``; re-shard if asked.

    ``shardings``: optional matching tree of jax.sharding.Sharding — this
    is the elastic path: the saved arrays are placed onto the *current*
    mesh regardless of the mesh they were saved from.
    """
    d = Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((d / _SENTINEL).read_text())
    flat_like = _flatten(like_tree)
    missing = set(flat_like) - set(manifest["arrays"])
    extra = set(manifest["arrays"]) - set(flat_like)
    if missing or extra:
        raise ValueError(f"checkpoint/tree mismatch: missing={missing} "
                         f"extra={extra}")
    flat_shard = _flatten(shardings) if shardings is not None else {}
    out = {}
    for k, meta in manifest["arrays"].items():
        arr = np.load(d / meta["file"])
        if verify and _hash(arr) != meta["hash"]:
            raise IOError(f"checkpoint corruption detected in {k}")
        arr = _decode(arr, meta["dtype"])
        if tuple(arr.shape) != tuple(flat_like[k].shape):
            raise ValueError(f"shape mismatch for {k}: saved {arr.shape} "
                             f"vs expected {flat_like[k].shape}")
        if k in flat_shard:
            out[k] = jax.device_put(arr, flat_shard[k])
        else:
            out[k] = jax.device_put(arr).astype(flat_like[k].dtype)
    # unflatten back into like_tree's structure
    leaves_with_path = jax.tree_util.tree_leaves_with_path(like_tree)
    keys = ["/".join(str(getattr(kk, "key", getattr(kk, "idx", kk)))
                     for kk in path) for path, _ in leaves_with_path]
    return jax.tree_util.tree_unflatten(
        _tree_def(like_tree), [out[k] for k in keys])
