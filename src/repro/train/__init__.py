from repro.train.checkpoint import save_checkpoint, restore_checkpoint  # noqa: F401
from repro.train.trainer import Trainer, TrainConfig  # noqa: F401
