"""cuConv: tap-decomposed direct convolution (the paper's contribution).

The paper decomposes a KH x KW convolution by *filter tap*: stage 1
computes, for every tap (i, j), the channel-axis dot product of filter
row F[:, i, j] with every input row — a plain GEMM per tap, over data
that is contiguous in the chosen layout with **no im2col transform**;
stage 2 sums the KH*KW per-tap partial matrices.  1x1 filters skip
stage 2 entirely (the paper's best-case region).

TPU adaptation (DESIGN.md §2): NHWC instead of NCHW so the channel
contraction is lane-contiguous; each per-tap GEMM maps onto the MXU.

All algorithms below are numerically equivalent (property-tested),
policy-free executor *functions*: each is wrapped by a registered
``core.executors.Executor`` declaring its capabilities, and which one
runs for a given configuration is decided exclusively by
``core.convspec.plan`` negotiating over that registry (DESIGN.md §4/§8),
which ``conv2d(..., algorithm="auto")`` wraps.  Every contraction
accumulates fp32 (``preferred_element_type``) so bf16 inputs keep
fp32 accumulation; outputs are cast back to the input dtype.

  lax              jax.lax.conv_general_dilated — the library baseline
                   (the cuDNN stand-in of the paper's comparison)
  im2col           explicit patch matrix + one GEMM — cuDNN "GEMM" variant
  cuconv_two_stage faithful paper algorithm: stage-1 temporaries
                   materialized (KH*KW, N, OH, OW, M), stage-2 sum
  cuconv_two_stage_pallas
                   the same pipeline on the Pallas stage-1/stage-2
                   kernels (stride 1) — the planner's VMEM fallback
  cuconv           beyond-paper fused tap accumulation (no temporaries);
                   the paper's "work-fusion" future-work realized
  cuconv_pallas    the fused Pallas TPU kernel (any stride, fused
                   bias/ReLU epilogue)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

# geometry helpers and the Pad alias have ONE home: core.convspec
# (aliased/re-exported here for brevity and back-compat)
from repro.core.convspec import Pad  # noqa: F401  (public re-export)
from repro.core.convspec import (normalize_pad as _norm_pad,
                                 normalize_stride as _norm_stride,
                                 out_size as _out_size)


def _pad_input(x, ph, pw):
    if ph == 0 and pw == 0:
        return x
    return jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))


# ---------------------------------------------------------------------------
# Baselines

def conv_lax(x, w, stride=1, padding: Pad = "same", groups=1):
    """Library convolution (XLA's native conv; the cuDNN analogue).

    ``groups`` maps to ``feature_group_count``: the only executor that
    runs grouped/depthwise specs exactly (filter depth is C/groups).
    """
    kh, kw = w.shape[0], w.shape[1]
    ph, pw = _norm_pad(padding, kh, kw)
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=_norm_stride(stride),
        padding=((ph, ph), (pw, pw)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
        preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def conv_im2col(x, w, stride=1, padding: Pad = "same"):
    """Explicit-GEMM convolution: materialize the patch matrix, one GEMM.

    This is the paper's "GEMM (explicit)" cuDNN baseline: the intermediate
    matrix duplicates input elements KH*KW-fold — the memory cost cuConv
    avoids.
    """
    kh, kw, C, M = w.shape
    ph, pw = _norm_pad(padding, kh, kw)
    sh, sw = _norm_stride(stride)
    xp = _pad_input(x, ph, pw)
    N = xp.shape[0]
    oh, ow = _out_size(x.shape[1], kh, ph, sh), _out_size(
        x.shape[2], kw, pw, sw)
    patches = jnp.stack(_tap_views(xp, kh, kw, oh, ow, (sh, sw)), axis=3)
    patches = patches.reshape(N * oh * ow, kh * kw * C)  # materialized!
    out = jnp.matmul(patches, w.reshape(kh * kw * C, M),
                     preferred_element_type=jnp.float32)
    return out.reshape(N, oh, ow, M).astype(x.dtype)


# ---------------------------------------------------------------------------
# cuConv: the paper's two stages

def _tap_views(xp, kh, kw, oh, ow, stride):
    """The KH*KW shifted input views (XLA slices, nothing materialized)."""
    N, _, _, C = xp.shape
    sh, sw = _norm_stride(stride)
    views = []
    for i in range(kh):
        for j in range(kw):
            views.append(jax.lax.slice(
                xp, (0, i, j, 0),
                (N, i + sh * (oh - 1) + 1, j + sw * (ow - 1) + 1, C),
                (1, sh, sw, 1)))
    return views


def cuconv_stage1(x, w, stride=1, padding: Pad = "same"):
    """Stage 1: per-tap channel contraction.

    Returns the paper's temporary tensor of shape (KH*KW, N, OH, OW, M):
    one (OH x OW) partial-result matrix per (tap, input, filter) triple.
    """
    kh, kw, C, M = w.shape
    ph, pw = _norm_pad(padding, kh, kw)
    sh, sw = _norm_stride(stride)
    xp = _pad_input(x, ph, pw)
    oh = _out_size(x.shape[1], kh, ph, sh)
    ow = _out_size(x.shape[2], kw, pw, sw)
    views = _tap_views(xp, kh, kw, oh, ow, (sh, sw))
    taps = w.reshape(kh * kw, C, M)
    outs = [jnp.einsum("nhwc,cm->nhwm", v, taps[t],
                       preferred_element_type=jnp.float32)
            for t, v in enumerate(views)]
    return jnp.stack(outs, axis=0)


def cuconv_stage2(temps):
    """Stage 2: sum the KH*KW per-tap partial matrices."""
    return jnp.sum(temps, axis=0)


def conv_cuconv_two_stage(x, w, stride=1, padding: Pad = "same"):
    """Faithful paper pipeline: materialized temporaries + separate sum.

    For 1x1 filters stage 2 is skipped (paper §3): stage 1's output *is*
    the convolution.
    """
    kh, kw = w.shape[0], w.shape[1]
    temps = cuconv_stage1(x, w, stride, padding)
    if kh == 1 and kw == 1:
        return temps[0].astype(x.dtype)
    return cuconv_stage2(temps).astype(x.dtype)


def conv_cuconv(x, w, stride=1, padding: Pad = "same"):
    """Fused tap accumulation (beyond-paper; no HBM temporaries)."""
    kh, kw, C, M = w.shape
    ph, pw = _norm_pad(padding, kh, kw)
    sh, sw = _norm_stride(stride)
    xp = _pad_input(x, ph, pw)
    oh = _out_size(x.shape[1], kh, ph, sh)
    ow = _out_size(x.shape[2], kw, pw, sw)
    taps = w.reshape(kh * kw, C, M)
    acc = None
    for t, v in enumerate(_tap_views(xp, kh, kw, oh, ow, (sh, sw))):
        y = jnp.einsum("nhwc,cm->nhwm", v, taps[t],
                       preferred_element_type=jnp.float32)
        acc = y if acc is None else acc + y
    return acc.astype(x.dtype)


def conv_cuconv_pallas(x, w, stride=1, padding: Pad = "same",
                       interpret: Optional[bool] = None):
    """Fused Pallas TPU kernel: any stride >= 1 (policy-free executor —
    VMEM budgeting lives in convspec.plan)."""
    from repro.kernels import ops
    kh, kw = w.shape[0], w.shape[1]
    ph, pw = _norm_pad(padding, kh, kw)
    return ops.cuconv_fused(x, w, (ph, pw), stride=_norm_stride(stride),
                            interpret=interpret)


def conv_conv1x1_pallas(x, w, stride=1, padding: Pad = "same",
                        interpret: Optional[bool] = None):
    """Dedicated 1x1 GEMM kernel: all N*H*W pixels flattened into MXU
    tiles — the paper's best-case region on its natural kernel."""
    kh, kw = w.shape[0], w.shape[1]
    if ((kh, kw) != (1, 1) or _norm_stride(stride) != (1, 1)
            or _norm_pad(padding, kh, kw) != (0, 0)):
        raise ValueError("conv1x1 kernel needs 1x1 filter, stride 1, pad 0; "
                         "plan() routes other specs elsewhere")
    from repro.kernels import ops
    return ops.conv1x1(x, w, interpret=interpret)


def conv_cuconv_two_stage_pallas(x, w, stride=1, padding: Pad = "same",
                                 interpret: Optional[bool] = None):
    """Faithful two-kernel Pallas pipeline (stride 1): stage-1 HBM
    temporaries + stage-2 sum — the planner's VMEM-bounded fallback."""
    if _norm_stride(stride) != (1, 1):
        raise ValueError("two-stage Pallas kernels are stride-1 only; "
                         "plan() routes strided specs elsewhere")
    from repro.kernels import ops
    kh, kw = w.shape[0], w.shape[1]
    ph, pw = _norm_pad(padding, kh, kw)
    return ops.cuconv_two_stage(x, w, (ph, pw), interpret=interpret)


def conv_winograd_pallas(x, w, stride=1, padding: Pad = "same",
                         interpret: Optional[bool] = None):
    """Tiled Pallas Winograd F(m,3) kernel (3x3 stride-1 only;
    policy-free executor — the F(m,3) variant and tile geometry come
    from the plan's launch config, default F(2x2,3x3))."""
    if (w.shape[0] != 3 or w.shape[1] != 3
            or _norm_stride(stride) != (1, 1)):
        raise ValueError("winograd_pallas needs 3x3 stride-1; "
                         "plan() routes other specs elsewhere")
    from repro.kernels import ops
    ph, pw = _norm_pad(padding, 3, 3)
    return ops.winograd_fused(x, w, (ph, pw), interpret=interpret)


def conv_direct(x, w, stride=1, padding: Pad = "same",
                interpret: Optional[bool] = None):
    """Im2col-free direct Pallas conv (Li et al. 1610.03618):
    channel-tiled VMEM accumulation, no patch matrix, any stride."""
    from repro.kernels import ops
    kh, kw = w.shape[0], w.shape[1]
    return ops.direct_conv(x, w, _norm_pad(padding, kh, kw),
                           _norm_stride(stride), interpret=interpret)


def conv_winograd_or_fallback(x, w, stride=1, padding: Pad = "same"):
    """Winograd F(2x2,3x3) for 3x3/stride-1, library conv otherwise —
    mirrors cuDNN exposing Winograd only where it is defined."""
    if (w.shape[0] == 3 and w.shape[1] == 3
            and _norm_stride(stride) == (1, 1)):
        from repro.core.winograd import conv_winograd
        return conv_winograd(x, w, 1, padding)
    return conv_lax(x, w, stride, padding)


# NOTE: there is deliberately no algorithm dict here any more.  The menu
# of executors — names, capabilities, cost models — lives in
# core/executors.py as registered Executor objects wrapping the pure
# functions above; `repro.core.executors.ALGORITHMS` is the back-compat
# {name: bare callable} view.


def conv2d(x, w, stride=1, padding: Pad = "same", algorithm="auto",
           bias=None, activation: Optional[str] = None, groups=1):
    """Public conv entry point: a thin wrapper over the ConvSpec planner.

    x: (N,H,W,C) NHWC; w: (KH,KW,C/groups,M) HWIO; bias: optional (M,);
    activation: None | 'relu' (anything else raises — no silent epilogue
    drop).  groups > 1 requests a grouped/depthwise conv, executed via
    the library's feature_group_count (plan() routes it there).
    algorithm="auto" lets plan() negotiate over the executor registry
    (measured cache > region claims > cheapest supported); naming a
    registered executor forces it, still subject to its declared
    capabilities (e.g. the fused kernel's VMEM budget).  The
    bias/activation epilogue is fused into the Pallas kernel when that
    path is planned, and applied as XLA ops otherwise.
    """
    from repro.core.convspec import ConvSpec, plan
    spec = ConvSpec.for_conv(x, w, stride, padding, bias=bias,
                             activation=activation, groups=groups)
    p = plan(spec, force=None if algorithm == "auto" else algorithm)
    return p(x, w, bias)
