"""Graph plan layer: plan a whole network once, serve it as a program.

The per-call ``conv2d`` path builds a ConvSpec and resolves a plan at
every call site, so nothing ever sees the network as a whole.  cuDNN
moved from per-call descriptors to a graph API for exactly this reason;
this module is that seam for the repo (DESIGN.md §5):

  ConvGraph   ordered chain of ConvSpec nodes — the conv skeleton of a
              network, derived from a model layer list + input geometry.
              ``signature()`` is its stable identity (the cache key).
  GraphPlan   per-node ConvPlans resolved ONCE, with a single
              ``explain()`` table for the whole network, a ``warmup()``
              that compiles (and optionally measure-autotunes) every
              node in one sweep, and ``run()`` to execute the chain.
  plan_graph  resolves a GraphPlan, consulting a persisted graph-level
              cache (``$REPRO_CACHE_DIR/graphplans.json``, next to
              ``autotune.json``) keyed by backend + graph signature —
              a warm process constructs the whole program with ZERO
              per-node plan() resolutions.

``models.cnn.SimpleCNN`` builds on this (one pre-resolved program per
input geometry instead of re-planning inside every conv block), and
``serve.cnn.CnnServeEngine`` multiplexes request streams onto a small
set of batch-bucketed GraphPlan programs.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.convspec import (ConvPlan, ConvSpec, normalize_pad,
                                 normalize_stride, plan, supports)
from repro.core.plancache import JsonCache

LayerSpec = Tuple[int, int, int, int]          # (kh, kw, c_out, stride)

# graph-level plan cache: {f"{backend}/{signature}": {"algorithms": [...]}}
_STORE = JsonCache("graphplans.json")


def clear_cache() -> None:
    """Drop the in-memory mirror (tests); the JSON file is untouched."""
    _STORE.clear()


@dataclasses.dataclass(frozen=True)
class ConvGraph:
    """Ordered chain of ConvSpec nodes: the conv skeleton of a network."""
    nodes: Tuple[ConvSpec, ...]

    def __post_init__(self):
        if not self.nodes:
            raise ValueError("ConvGraph needs at least one node")
        for a, b in zip(self.nodes, self.nodes[1:]):
            if a.out_shape != b.in_shape:
                raise ValueError(f"graph chain broken: {a.key()} produces "
                                 f"{a.out_shape} but next node consumes "
                                 f"{b.in_shape}")

    @classmethod
    def chain(cls, layers: Sequence[LayerSpec], in_shape, *,
              padding="same", dtype: str = "float32",
              epilogue: str = "bias_relu") -> "ConvGraph":
        """Derive the spec chain from a layer list + input geometry.

        ``layers`` uses the SimpleCNN convention ``(kh, kw, c_out,
        stride)``; each node's output geometry feeds the next node.
        """
        n, h, w, c = map(int, in_shape)
        nodes: List[ConvSpec] = []
        for kh, kw, co, s in layers:
            spec = ConvSpec((n, h, w, c), (kh, kw, c, co),
                            normalize_stride(s), normalize_pad(padding, kh, kw),
                            dtype, epilogue)
            nodes.append(spec)
            _, h, w, c = spec.out_shape
        return cls(tuple(nodes))

    @property
    def in_shape(self) -> Tuple[int, int, int, int]:
        return self.nodes[0].in_shape

    @property
    def out_shape(self) -> Tuple[int, int, int, int]:
        return self.nodes[-1].out_shape

    def signature(self) -> str:
        """Stable graph identity: the persisted plan cache's key material."""
        blob = "|".join(s.key() for s in self.nodes)
        return hashlib.sha1(blob.encode()).hexdigest()[:16]

    def __len__(self) -> int:
        return len(self.nodes)


@dataclasses.dataclass
class GraphPlan:
    """Whole-network plan: one resolved ConvPlan per graph node.

    Mutable only through ``warmup(measure=True)``, which may swap node
    plans for measured winners; execution itself never re-plans.
    """
    graph: ConvGraph
    node_plans: Tuple[ConvPlan, ...]
    backend: str
    source: str                  # resolved | graph_cache | forced
    # per-node jitted executables, shared by warmup() and run() so the
    # warmup compile sweep is the same program inference reuses
    _jitted: Dict[int, Callable] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    def _node_fn(self, i: int) -> Callable:
        fn = self._jitted.get(i)
        if fn is None:
            fn = jax.jit(self.node_plans[i])
            self._jitted[i] = fn
        return fn

    def explain(self) -> str:
        """One aligned table for the whole network."""
        lines = [f"GraphPlan[{self.source}] backend={self.backend} "
                 f"sig={self.graph.signature()} nodes={len(self.graph)}"]
        for i, p in enumerate(self.node_plans):
            s = p.spec
            n, h, w, c = s.in_shape
            kh, kw, _, m = s.filter_shape
            lines.append(
                f"  {i:3d}  {h:>3d}x{w:<3d} c{c:<4d} {kh}x{kw}/"
                f"{s.stride[0]} m{m:<4d} -> {p.algorithm:24s} "
                f"[{p.source}] {p.reason}")
        return "\n".join(lines)

    # -- execution -------------------------------------------------------
    def run(self, x, weights: Sequence):
        """Execute the conv chain on ``x``.

        ``weights``: one ``(w, bias)`` pair (bias may be None for
        epilogues without bias) per node, in graph order.  No plan()
        resolution happens here — the program was resolved up front.
        """
        if len(weights) != len(self.graph):
            raise ValueError(f"graph has {len(self.graph)} nodes but got "
                             f"{len(weights)} weight pairs")
        for i, (p, (w, b)) in enumerate(zip(self.node_plans, weights)):
            x = self._node_fn(i)(x, w, b if p.spec.has_bias else None)
        return x

    # -- warmup / autotune ----------------------------------------------
    def warmup(self, *, measure: bool = False, repeats: int = 3) -> Dict:
        """Compile (and optionally measure-autotune) every node, one sweep.

        ``measure=True`` runs the exhaustive per-node timing sweep
        (``autotune.measure_algorithm`` with the node's epilogue threaded
        through), re-resolves each node against the freshly persisted
        winners, and re-persists the graph-level entry — after which the
        plan serves inference with zero further plan() resolutions.

        Returns ``{"nodes": [...], "total_ms": float}`` with per-node
        algorithm/source/compile-time rows.
        """
        from repro.core import autotune
        if measure and self.backend != jax.default_backend():
            # measure_algorithm times on the process's default backend;
            # recording those numbers under another backend's key would
            # silently discard the sweep
            raise ValueError(
                f"measured warmup must run on the plan's backend: plan is "
                f"for {self.backend!r} but this process runs "
                f"{jax.default_backend()!r}")
        t_start = time.perf_counter()
        if measure:
            new_plans: List[ConvPlan] = []
            for p in self.node_plans:
                s = p.spec
                dtype = jnp.dtype(s.dtype)
                autotune.measure_algorithm(
                    jnp.zeros(s.in_shape, dtype),
                    jnp.zeros(s.filter_shape, dtype),
                    stride=s.stride, padding=s.padding, repeats=repeats,
                    bias=(jnp.zeros((s.filter_shape[3],), dtype)
                          if s.has_bias else None),
                    activation="relu" if s.wants_relu else None)
                new_plans.append(plan(s, backend=self.backend))  # the winner
            self.node_plans = tuple(new_plans)
            self._jitted.clear()        # stale traces must not serve on
            _persist(self.graph, self.backend, self.node_plans)
        rows = []
        for i, p in enumerate(self.node_plans):
            s = p.spec
            dtype = jnp.dtype(s.dtype)
            x = jnp.zeros(s.in_shape, dtype)
            w = jnp.zeros(s.filter_shape, dtype)
            b = jnp.zeros((s.filter_shape[3],), dtype) if s.has_bias else None
            t0 = time.perf_counter()
            self._node_fn(i)(x, w, b).block_until_ready()
            rows.append({"key": s.key(), "algorithm": p.algorithm,
                         "source": p.source,
                         "compile_ms": (time.perf_counter() - t0) * 1e3})
        return {"nodes": rows,
                "total_ms": (time.perf_counter() - t_start) * 1e3}


# ---------------------------------------------------------------------------
# resolution + persisted graph-level cache

def plan_graph(graph: ConvGraph, *, backend: Optional[str] = None,
               force: Optional[str] = None,
               use_cache: bool = True) -> GraphPlan:
    """Resolve a whole-network plan once.

    Forced plans bypass the persisted cache in both directions (they are
    a debugging/benchmark tool, not a deployment choice).  Otherwise a
    persisted entry keyed by backend + graph signature reconstructs the
    program with zero per-node plan() resolutions; entries naming
    unknown or no-longer-supported algorithms are dropped and re-solved.
    """
    backend = backend or jax.default_backend()
    if force is not None:
        plans = tuple(plan(s, force=force, backend=backend)
                      for s in graph.nodes)
        return GraphPlan(graph, plans, backend, "forced")
    if use_cache:
        cached = _plans_from_cache(graph, backend)
        if cached is not None:
            return GraphPlan(graph, cached, backend, "graph_cache")
    plans = tuple(plan(s, backend=backend) for s in graph.nodes)
    if use_cache:       # use_cache=False means no cache interaction AT ALL
        _persist(graph, backend, plans)
    return GraphPlan(graph, plans, backend, "resolved")


def _graph_key(graph: ConvGraph, backend: str) -> str:
    return f"{backend}/{graph.signature()}"


def _persist(graph: ConvGraph, backend: str,
             plans: Sequence[ConvPlan]) -> None:
    _STORE.put(_graph_key(graph, backend),
               {"algorithms": [p.algorithm for p in plans]})


def _plans_from_cache(graph: ConvGraph,
                      backend: str) -> Optional[Tuple[ConvPlan, ...]]:
    from repro.core import autotune
    from repro.core.cuconv import ALGORITHMS
    entry = _STORE.get(_graph_key(graph, backend))
    if not isinstance(entry, dict):
        return None
    algos = entry.get("algorithms")
    if not isinstance(algos, list) or len(algos) != len(graph.nodes):
        return None
    plans = []
    for spec, algo in zip(graph.nodes, algos):
        if algo not in ALGORITHMS or not supports(algo, spec)[0]:
            return None                 # stale entry: caller re-resolves
        # a measured winner recorded since this entry was persisted must
        # win (plan()'s measured > heuristic precedence survives the
        # graph layer): treat the entry as stale and re-resolve
        measured = autotune.cached_best(spec, backend)
        if (measured is not None and measured != algo
                and supports(measured, spec)[0]):
            return None
        plans.append(ConvPlan(spec, algo, "graph_cache",
                              "persisted graph-level plan", backend))
    return tuple(plans)
