"""Typed operator-IR graph layer: plan a whole network once, serve it.

The per-call ``conv2d`` path builds a ConvSpec and resolves a plan at
every call site; the first graph layer chained same-epilogue convs but
could not express what the paper's evaluation networks actually contain
(residual adds, pooling, fire-module concats, grouped/depthwise convs).
cuDNN moved from per-op descriptors to a graph API for exactly this
reason; this module is that seam for the repo (DESIGN.md §6):

  OpSpec      typed IR node, one frozen dataclass per operator:
              ConvOp (a ConvSpec — including grouped/depthwise),
              PoolOp (max/avg), AddOp (residual, optional ReLU),
              ConcatOp (channel axis), GapOp, DenseOp.  Nodes are
              *named* and name their input edges explicitly.
  Graph       a DAG of OpSpec nodes in topological order, shape-checked
              at construction (every edge's producer shape must satisfy
              the consumer).  ``signature()`` is its stable identity —
              schema-versioned key material for the persisted cache.
  GraphPlan   per-conv-node ConvPlans resolved ONCE (keyed by node
              name), one ``explain()`` table for the whole network, a
              ``warmup()`` compile/measure sweep, ``run()`` to execute
              the DAG.
  plan_graph  resolves a GraphPlan, consulting a persisted graph-level
              cache (``$REPRO_CACHE_DIR/graphplans.json``) keyed by
              backend + signature — a warm process constructs the whole
              program with ZERO per-node plan() resolutions.  Entries
              carry a ``schema`` field; unversioned or mismatched
              entries are dropped, never misread.
  PrecisionPolicy
              graph-wide compute dtype (default + per-node overrides)
              landing in each conv node's ``ConvSpec.dtype``, so a whole
              network plans/autotunes/serves in bf16 end to end with
              precision-distinct cache keys (fp32 accumulation is the
              executors' declared behavior).

``ConvGraph`` (the PR-2 chained-ConvSpec API) survives as a thin
compatibility constructor that lowers to the IR; ``plan_graph`` accepts
either.  ``models.cnn`` builds whole forward passes — pools, residuals,
depthwise stages, GAP + dense head — as one planned, bucketable program
that ``serve.cnn.CnnServeEngine`` multiplexes request streams onto.
"""
from __future__ import annotations

import dataclasses
import hashlib
import re
import time
from typing import (Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple, Union)

import jax
import jax.numpy as jnp

from repro.core.convspec import (ConvPlan, ConvSpec, canonical_dtype,
                                 normalize_pad, normalize_stride, out_size,
                                 plan, resolve_config)
from repro.core.plancache import JsonCache

LayerSpec = Tuple[int, int, int, int]          # (kh, kw, c_out, stride)


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Graph-wide compute-dtype policy: one default plus per-node
    overrides.

    ``PrecisionPolicy("bf16")`` plans every conv node in bfloat16 (all
    built-in executors accumulate fp32 for bf16 inputs — their declared
    ``accum`` behavior); ``overrides={"stem": "fp32"}`` pins named conv
    nodes to another dtype (e.g. a numerically sensitive stem; only
    conv nodes carry a planned dtype, and ``GraphBuilder`` rejects
    overrides naming anything else).  The
    policy lands in each node's ``ConvSpec.dtype``, so every cache key —
    measured autotune, graph signature, persisted graphplans entries —
    is precision-distinct by construction: a bf16 plan can never serve
    an fp32 graph, or vice versa.

    Master params stay fp32; executors cast operands to the node dtype
    at execution time.
    """
    default: str = "float32"
    overrides: Tuple[Tuple[str, str], ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "default", canonical_dtype(self.default))
        ovr = self.overrides
        if isinstance(ovr, Mapping):
            ovr = tuple(sorted(ovr.items()))
        object.__setattr__(self, "overrides", tuple(
            (str(name), canonical_dtype(dt)) for name, dt in ovr))

    @classmethod
    def of(cls, value) -> "PrecisionPolicy":
        """Coerce any accepted spelling (policy | dtype string/dtype |
        None) into a policy; None means fp32."""
        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        return cls(canonical_dtype(value))

    def dtype_for(self, node_name: str) -> str:
        for name, dt in self.overrides:
            if name == node_name:
                return dt
        return self.default

    def key(self) -> str:
        """Stable identity for plan-memo keys."""
        if not self.overrides:
            return self.default
        ovr = ",".join(f"{n}={d}" for n, d in self.overrides)
        return f"{self.default}[{ovr}]"

    def quantizer(self):
        """The quantization policy riding this precision policy, or None.

        Plain precision policies never quantize; ``quant.QuantPolicy``
        overrides this to return itself — the one hook ``plan_graph``
        threading keys off, so fp callers pay nothing.
        """
        return None

# Persisted graph-plan entry schema.  v1 was the positional
# {"algorithms": [...]} list of the chain era (implicitly unversioned);
# v2 is {"schema": 2, "algorithms": {node_name: algo}} over the IR.
GRAPH_SCHEMA = 2

# graph-level plan cache: {f"{backend}/{signature}": entry}
_STORE = JsonCache("graphplans.json")


def clear_cache() -> None:
    """Drop the in-memory mirror (tests); the JSON file is untouched."""
    _STORE.clear()


# ---------------------------------------------------------------------------
# the operator IR

_NAME_RE = re.compile(r"[A-Za-z0-9_.\-]+")


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """Base IR node: a named operator with explicit input edges."""
    name: str
    inputs: Tuple[str, ...]

    op = "op"                    # overridden per subclass

    def __post_init__(self):
        # names are signature key material: restrict them to a charset
        # disjoint from descriptor() delimiters so signatures can never
        # be ambiguous
        for n in (self.name,) + tuple(self.inputs):
            if not _NAME_RE.fullmatch(n):
                raise ValueError(f"node/edge names must match "
                                 f"[A-Za-z0-9_.-]+; got {n!r}")
        if not self.inputs:
            raise ValueError(f"node {self.name!r} has no inputs")

    # -- IR contract per subclass ---------------------------------------
    def infer_shape(self, in_shapes: Sequence[Tuple[int, ...]]) -> Tuple:
        raise NotImplementedError

    def descriptor(self) -> str:
        """Stable per-node key material (feeds Graph.signature())."""
        return f"{self.op}:{self.name}<{','.join(self.inputs)}>"


@dataclasses.dataclass(frozen=True)
class ConvOp(OpSpec):
    """A planned convolution node (the only node kind plan() resolves).

    A spec carrying a cross-layer ``fused_add`` (the fusion pass's
    residual fold) takes a SECOND input edge — the shortcut operand,
    shape-checked against the conv's output shape; a ``fused_pool``
    spec keeps one input but yields the pooled ``final_shape``.
    """
    spec: ConvSpec = None

    op = "conv"

    def __post_init__(self):
        super().__post_init__()
        if not isinstance(self.spec, ConvSpec):
            raise ValueError(f"conv node {self.name!r} needs a ConvSpec")
        want = 2 if self.spec.fused_add != "none" else 1
        if len(self.inputs) != want:
            raise ValueError(
                f"conv node {self.name!r} takes exactly {want} input(s) "
                f"(fused_add={self.spec.fused_add!r}); got {self.inputs}")

    def infer_shape(self, in_shapes):
        s = in_shapes[0]
        if tuple(s) != self.spec.in_shape:
            raise ValueError(f"conv node {self.name!r} expects input shape "
                             f"{self.spec.in_shape} but edge "
                             f"{self.inputs[0]!r} produces {tuple(s)}")
        if self.spec.fused_add != "none":
            a = tuple(in_shapes[1])
            if a != self.spec.out_shape:
                raise ValueError(
                    f"conv node {self.name!r}: fused-add operand "
                    f"{self.inputs[1]!r} has shape {a} but the conv "
                    f"produces {self.spec.out_shape}")
        return self.spec.final_shape

    def descriptor(self):
        return f"{super().descriptor()}:{self.spec.key()}"


@dataclasses.dataclass(frozen=True)
class PoolOp(OpSpec):
    """Windowed max/avg pooling (NHWC)."""
    kind: str = "max"                         # max | avg
    window: Tuple[int, int] = (2, 2)
    stride: Tuple[int, int] = (2, 2)
    padding: Tuple[int, int] = (0, 0)

    op = "pool"

    def __post_init__(self):
        super().__post_init__()
        if self.kind not in ("max", "avg"):
            raise ValueError(f"pool node {self.name!r}: kind must be "
                             f"'max' or 'avg'; got {self.kind!r}")
        if len(self.inputs) != 1:
            raise ValueError(f"pool node {self.name!r} takes exactly one "
                             f"input; got {self.inputs}")

    def infer_shape(self, in_shapes):
        (s,) = in_shapes
        if len(s) != 4:
            raise ValueError(f"pool node {self.name!r} needs an NHWC "
                             f"input; got shape {tuple(s)}")
        n, h, w, c = s
        (kh, kw), (sh, sw), (ph, pw) = self.window, self.stride, self.padding
        oh, ow = out_size(h, kh, ph, sh), out_size(w, kw, pw, sw)
        if oh <= 0 or ow <= 0:
            raise ValueError(f"pool node {self.name!r} produces empty "
                             f"output from input {tuple(s)}")
        return (n, oh, ow, c)

    def descriptor(self):
        return (f"{super().descriptor()}:{self.kind}{self.window[0]}x"
                f"{self.window[1]}s{self.stride[0]}x{self.stride[1]}"
                f"p{self.padding[0]}x{self.padding[1]}")


@dataclasses.dataclass(frozen=True)
class AddOp(OpSpec):
    """Elementwise sum of >= 2 same-shape inputs (residual connections);
    optional fused ReLU after the add (the post-residual activation)."""
    activation: str = "none"                  # none | relu

    op = "add"

    def __post_init__(self):
        super().__post_init__()
        if len(self.inputs) < 2:
            raise ValueError(f"add node {self.name!r} needs >= 2 inputs")
        if self.activation not in ("none", "relu"):
            raise ValueError(f"add node {self.name!r}: activation must be "
                             f"'none' or 'relu'; got {self.activation!r}")

    def infer_shape(self, in_shapes):
        first = tuple(in_shapes[0])
        for edge, s in zip(self.inputs, in_shapes):
            if tuple(s) != first:
                raise ValueError(
                    f"add node {self.name!r}: input {edge!r} has shape "
                    f"{tuple(s)} but {self.inputs[0]!r} has {first}")
        return first

    def descriptor(self):
        return f"{super().descriptor()}:{self.activation}"


@dataclasses.dataclass(frozen=True)
class ConcatOp(OpSpec):
    """Channel-axis concatenation (fire-module expand branches)."""

    op = "concat"

    def __post_init__(self):
        super().__post_init__()
        if len(self.inputs) < 2:
            raise ValueError(f"concat node {self.name!r} needs >= 2 inputs")

    def infer_shape(self, in_shapes):
        lead = tuple(in_shapes[0][:-1])
        for edge, s in zip(self.inputs, in_shapes):
            if tuple(s[:-1]) != lead:
                raise ValueError(
                    f"concat node {self.name!r}: input {edge!r} has "
                    f"non-channel dims {tuple(s[:-1])} but "
                    f"{self.inputs[0]!r} has {lead}")
        return lead + (sum(int(s[-1]) for s in in_shapes),)


@dataclasses.dataclass(frozen=True)
class GapOp(OpSpec):
    """Global average pool: (N, H, W, C) -> (N, C) (the classifier neck)."""

    op = "gap"

    def __post_init__(self):
        super().__post_init__()
        if len(self.inputs) != 1:
            raise ValueError(f"gap node {self.name!r} takes exactly one "
                             f"input; got {self.inputs}")

    def infer_shape(self, in_shapes):
        (s,) = in_shapes
        if len(s) != 4:
            raise ValueError(f"gap node {self.name!r} needs an NHWC "
                             f"input; got shape {tuple(s)}")
        return (s[0], s[3])


@dataclasses.dataclass(frozen=True)
class DenseOp(OpSpec):
    """Linear head: (N, C) @ (C, K) [+ b] -> (N, K)."""
    features: Tuple[int, int] = None          # (c_in, c_out)
    bias: bool = True

    op = "dense"

    def __post_init__(self):
        super().__post_init__()
        if (not isinstance(self.features, tuple) or len(self.features) != 2
                or any(int(f) < 1 for f in self.features)):
            raise ValueError(f"dense node {self.name!r} needs features="
                             f"(c_in, c_out); got {self.features!r}")
        if len(self.inputs) != 1:
            raise ValueError(f"dense node {self.name!r} takes exactly one "
                             f"input; got {self.inputs}")

    def infer_shape(self, in_shapes):
        (s,) = in_shapes
        if len(s) != 2 or int(s[1]) != self.features[0]:
            raise ValueError(f"dense node {self.name!r} needs input "
                             f"(N, {self.features[0]}); got {tuple(s)}")
        return (s[0], self.features[1])

    def descriptor(self):
        return (f"{super().descriptor()}:{self.features[0]}x"
                f"{self.features[1]}:bias={int(self.bias)}")


@dataclasses.dataclass(frozen=True, eq=False)
class Graph:
    """A DAG of named OpSpec nodes over one graph input.

    ``nodes`` must be in topological order (every edge names the graph
    input or an earlier node — which also rules out cycles); shapes are
    inferred and checked edge-by-edge at construction.  ``output`` names
    the node whose value ``run`` returns (default: the last node).
    """
    nodes: Tuple[OpSpec, ...]
    in_shape: Tuple[int, ...]
    input_name: str = "input"
    output: Optional[str] = None

    def __post_init__(self):
        if not self.nodes:
            raise ValueError("Graph needs at least one node")
        shapes: Dict[str, Tuple[int, ...]] = {
            self.input_name: tuple(map(int, self.in_shape))}
        for node in self.nodes:
            if node.name in shapes:
                raise ValueError(f"duplicate node name {node.name!r}")
            missing = [e for e in node.inputs if e not in shapes]
            if missing:
                raise ValueError(
                    f"node {node.name!r} consumes undefined edge(s) "
                    f"{missing}: nodes must be listed after their inputs "
                    f"(topological order; cycles are impossible)")
            shapes[node.name] = node.infer_shape(
                [shapes[e] for e in node.inputs])
        out = self.output if self.output is not None else self.nodes[-1].name
        if out not in shapes or out == self.input_name:
            raise ValueError(f"output {out!r} is not a node of the graph")
        object.__setattr__(self, "output", out)
        object.__setattr__(self, "shapes", shapes)

    # -- derived ---------------------------------------------------------
    @property
    def out_shape(self) -> Tuple[int, ...]:
        return self.shapes[self.output]

    @property
    def conv_nodes(self) -> Tuple[ConvOp, ...]:
        return tuple(n for n in self.nodes if isinstance(n, ConvOp))

    def node(self, name: str) -> OpSpec:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def signature(self) -> str:
        """Stable graph identity: schema-versioned key material for the
        persisted plan cache."""
        blob = "|".join(
            [f"v{GRAPH_SCHEMA}", f"in{tuple(self.in_shape)}",
             f"out:{self.output}"] + [n.descriptor() for n in self.nodes])
        return hashlib.sha1(blob.encode()).hexdigest()[:16]

    def __len__(self) -> int:
        return len(self.nodes)


class GraphBuilder:
    """Incremental Graph construction with shape threading.

    Each method appends one named node consuming named edges and returns
    the node name, so network definitions read as dataflow:

        b = GraphBuilder((1, 32, 32, 3))
        y = b.conv("stem", "input", 3, 16)
        y = b.pool("pool", y)
        ...
        b.graph()

    Shapes are tracked as nodes are added (conv specs are derived from
    the producer's shape), and the finished ``Graph`` re-validates the
    whole DAG at construction.
    """

    def __init__(self, in_shape, dtype: Union[str, PrecisionPolicy] = "float32",
                 input_name: str = "input"):
        self.in_shape = tuple(map(int, in_shape))
        # ``dtype`` accepts a plain dtype string (every node) or a
        # PrecisionPolicy (default + per-node overrides); model builders
        # pass through whatever GraphModel.graph hands them
        self.precision = PrecisionPolicy.of(dtype)
        self.input_name = input_name
        self.nodes: List[OpSpec] = []
        self.shapes: Dict[str, Tuple[int, ...]] = {
            input_name: self.in_shape}

    @property
    def dtype(self) -> str:
        return self.precision.default

    def _put(self, node: OpSpec) -> str:
        self.shapes[node.name] = node.infer_shape(
            [self.shapes[e] for e in node.inputs])
        self.nodes.append(node)
        return node.name

    def conv(self, name: str, src: str, k, c_out: int, *, stride=1,
             padding="same", epilogue: str = "bias_relu",
             groups: int = 1) -> str:
        kh, kw = (k, k) if isinstance(k, int) else k
        in_shape = self.shapes[src]
        spec = ConvSpec(in_shape, (kh, kw, in_shape[3] // groups, c_out),
                        normalize_stride(stride),
                        normalize_pad(padding, kh, kw),
                        self.precision.dtype_for(name), epilogue, groups)
        return self._put(ConvOp(name, (src,), spec))

    def pool(self, name: str, src: str, *, kind: str = "max", window=2,
             stride=None, padding=0) -> str:
        win = (window, window) if isinstance(window, int) else tuple(window)
        stride = win if stride is None else (
            (stride, stride) if isinstance(stride, int) else tuple(stride))
        pad = (padding, padding) if isinstance(padding, int) \
            else tuple(padding)
        return self._put(PoolOp(name, (src,), kind, win, stride, pad))

    def add(self, name: str, srcs: Sequence[str], *,
            activation: str = "none") -> str:
        return self._put(AddOp(name, tuple(srcs), activation))

    def concat(self, name: str, srcs: Sequence[str]) -> str:
        return self._put(ConcatOp(name, tuple(srcs)))

    def gap(self, name: str, src: str) -> str:
        return self._put(GapOp(name, (src,)))

    def dense(self, name: str, src: str, c_out: int, *,
              bias: bool = True) -> str:
        c_in = int(self.shapes[src][-1])
        return self._put(DenseOp(name, (src,), (c_in, c_out), bias))

    def graph(self, output: Optional[str] = None) -> Graph:
        # a precision override that names no CONV node is a typo (or a
        # pool/add/dense node, which carries no planned dtype) and would
        # silently no-op — exactly the numerics it was written to protect
        convs = {n.name for n in self.nodes if isinstance(n, ConvOp)}
        ghosts = [n for n, _ in self.precision.overrides if n not in convs]
        if ghosts:
            raise ValueError(
                f"PrecisionPolicy overrides name non-conv node(s) "
                f"{ghosts}; only conv nodes plan a dtype — conv nodes "
                f"here: {sorted(convs)}")
        return Graph(tuple(self.nodes), self.in_shape,
                     self.input_name, output)


# ---------------------------------------------------------------------------
# back-compat: the chained-ConvSpec constructor, lowering to the IR

@dataclasses.dataclass(frozen=True)
class ConvGraph:
    """Ordered chain of ConvSpec nodes (the pre-IR graph API).

    Kept as a thin compatibility constructor: ``plan_graph`` lowers it
    to a ``Graph`` of conv nodes named ``conv0..convN`` via ``to_ir()``
    (see README "Migrating from ConvGraph.chain").
    """
    nodes: Tuple[ConvSpec, ...]

    def __post_init__(self):
        if not self.nodes:
            raise ValueError("ConvGraph needs at least one node")
        for a, b in zip(self.nodes, self.nodes[1:]):
            if a.out_shape != b.in_shape:
                raise ValueError(f"graph chain broken: {a.key()} produces "
                                 f"{a.out_shape} but next node consumes "
                                 f"{b.in_shape}")

    @classmethod
    def chain(cls, layers: Sequence[LayerSpec], in_shape, *,
              padding="same", dtype: str = "float32",
              epilogue: Union[str, Sequence[str]] = "bias_relu"
              ) -> "ConvGraph":
        """Derive the spec chain from a layer list + input geometry.

        ``layers`` uses the SimpleCNN convention ``(kh, kw, c_out,
        stride)``; each node's output geometry feeds the next node.
        ``epilogue`` is one epilogue for every layer, or a per-layer
        sequence (e.g. ``bias_relu`` everywhere but a final ``bias`` on
        a classifier's last conv).
        """
        if isinstance(epilogue, str):
            epilogues = [epilogue] * len(layers)
        else:
            epilogues = list(epilogue)
            if len(epilogues) != len(layers):
                raise ValueError(f"epilogue sequence has {len(epilogues)} "
                                 f"entries for {len(layers)} layers")
        n, h, w, c = map(int, in_shape)
        nodes: List[ConvSpec] = []
        for (kh, kw, co, s), epi in zip(layers, epilogues):
            spec = ConvSpec((n, h, w, c), (kh, kw, c, co),
                            normalize_stride(s), normalize_pad(padding, kh, kw),
                            dtype, epi)
            nodes.append(spec)
            _, h, w, c = spec.out_shape
        return cls(tuple(nodes))

    @property
    def in_shape(self) -> Tuple[int, int, int, int]:
        return self.nodes[0].in_shape

    @property
    def out_shape(self) -> Tuple[int, int, int, int]:
        return self.nodes[-1].out_shape

    def to_ir(self) -> Graph:
        """Lower the chain to the operator IR: conv nodes ``conv{i}``,
        each consuming its predecessor."""
        prev, ops = "input", []
        for i, spec in enumerate(self.nodes):
            name = f"conv{i}"
            ops.append(ConvOp(name, (prev,), spec))
            prev = name
        return Graph(tuple(ops), self.in_shape)

    def signature(self) -> str:
        """Stable graph identity — the lowered IR's signature, so chain
        callers and IR callers share one cache namespace."""
        return self.to_ir().signature()

    def __len__(self) -> int:
        return len(self.nodes)


GraphLike = Union[Graph, ConvGraph]


def _as_ir(graph: GraphLike) -> Graph:
    return graph.to_ir() if isinstance(graph, ConvGraph) else graph


# ---------------------------------------------------------------------------
# cross-layer fusion pass (DESIGN.md §10)

def fuse_graph(graph: Graph, backend: Optional[str] = None
               ) -> Tuple[Graph, Dict[str, str]]:
    """Planning-time IR rewrite: fold fusable consumers into conv nodes.

    Two rewrite rules, applied to fixpoint:

      add   An ``AddOp`` over two edges where one producer is a conv
            with no other consumer, no existing fusion, and epilogue
            ``none``/``bias`` folds into that conv (latest such producer
            in topological order wins).  The conv absorbs the add's
            activation (``fused_add="add"|"add_relu"``), gains the OTHER
            edge as a second input (the shortcut operand), and moves to
            the add's slot — so a ``resnet_like`` shortcut join executes
            inside the conv kernel's epilogue.
      pool  A ``PoolOp`` whose single-consumer conv producer has no
            existing fusion folds into the conv as ``fused_pool``; the
            conv output tile stays in VMEM and is pooled before the
            single writeback.

    Each rewrite is capability-negotiated: it only fires when at least
    one registered executor ``supports()`` the fused spec (executors
    declare fusable forms via ``fusions()``) AND a persisted
    ``tune="full"`` measurement has not ruled the fusion a loss
    (``autotune.fusion_verdict``; unmeasured specs fuse optimistically).
    No ``plan()`` resolution happens here — the pass is pure rewriting,
    so the persisted-cache hit path stays zero-resolution.

    Returns ``(fused_graph, provenance)`` where provenance maps each
    fused conv node name to ``"add:<consumed>"`` / ``"pool:<consumed>"``.
    The original graph object is returned unchanged when nothing fuses.
    """
    from repro.core import autotune, executors
    backend = backend or jax.default_backend()
    nodes: List[OpSpec] = list(graph.nodes)
    output = graph.output
    fused: Dict[str, str] = {}

    def _rename(ns: List[OpSpec], old: str, new: str) -> List[OpSpec]:
        out = []
        for n in ns:
            if old in n.inputs:
                n = dataclasses.replace(n, inputs=tuple(
                    new if e == old else e for e in n.inputs))
            out.append(n)
        return out

    progress = True
    while progress:
        progress = False
        counts: Dict[str, int] = {}
        for n in nodes:
            for e in n.inputs:
                counts[e] = counts.get(e, 0) + 1
        counts[output] = counts.get(output, 0) + 1   # graph output consumes
        index = {n.name: i for i, n in enumerate(nodes)}
        for i, node in enumerate(nodes):
            if isinstance(node, AddOp) and len(node.inputs) == 2:
                best = None
                for pos, e in enumerate(node.inputs):
                    j = index.get(e)
                    if j is None:                    # the graph input
                        continue
                    prod = nodes[j]
                    if (not isinstance(prod, ConvOp)
                            or counts.get(e, 0) != 1
                            or prod.spec.has_fusion
                            or prod.spec.epilogue not in ("none", "bias")):
                        continue
                    if best is None or j > best[0]:
                        best = (j, pos)
                if best is None:
                    continue
                j, pos = best
                conv = nodes[j]
                mode = "add_relu" if node.activation == "relu" else "add"
                spec = dataclasses.replace(conv.spec, fused_add=mode)
                new_inputs = (conv.inputs[0], node.inputs[1 - pos])
                kind = "add"
            elif isinstance(node, PoolOp):
                j = index.get(node.inputs[0])
                if j is None:
                    continue
                conv = nodes[j]
                if (not isinstance(conv, ConvOp)
                        or counts.get(node.inputs[0], 0) != 1
                        or conv.spec.has_fusion):
                    continue
                spec = dataclasses.replace(
                    conv.spec,
                    fused_pool=(node.kind,
                                node.window[0], node.window[1],
                                node.stride[0], node.stride[1],
                                node.padding[0], node.padding[1]))
                new_inputs = conv.inputs
                kind = "pool"
            else:
                continue
            # capability + measured arbitration gates: some executor
            # must support the fused form, and a persisted tune="full"
            # measurement saying the fusion LOSES keeps it unfused
            if not executors.supporting(spec):
                continue
            if autotune.fusion_verdict(spec, backend) is False:
                continue
            fused[conv.name] = f"{kind}:{node.name}"
            # the conv moves into the consumed node's slot (all of its
            # inputs are defined there, and nothing between consumed it)
            nodes[i] = ConvOp(conv.name, new_inputs, spec)
            del nodes[j]
            if output == node.name:
                output = conv.name
            nodes = _rename(nodes, node.name, conv.name)
            progress = True
            break

    if not fused:
        return graph, {}
    return Graph(tuple(nodes), graph.in_shape, graph.input_name,
                 output), fused


# ---------------------------------------------------------------------------
# the planned program

@dataclasses.dataclass
class GraphPlan:
    """Whole-network plan: one resolved ConvPlan per conv node, keyed by
    node name.

    Mutable only through ``warmup(tune=...)`` (``measure=True`` is the
    back-compat spelling of ``tune="algo"``), which may swap node plans
    for measured ``(algorithm, launch config)`` winners; execution
    itself never re-plans.
    """
    graph: Graph
    conv_plans: Dict[str, ConvPlan]
    backend: str
    source: str                  # resolved | graph_cache | forced
    # fusion provenance: {conv node: "add:<consumed>" | "pool:<consumed>"}
    fused: Dict[str, str] = dataclasses.field(default_factory=dict)
    # the pre-fusion IR (None when the pass was disabled): the persisted
    # cache key stays the UNFUSED signature, and tune="full" re-runs the
    # pass from here so measured fused-vs-unfused verdicts can flip a
    # rewrite on or off
    base_graph: Optional[Graph] = None
    # quantization provenance: {conv node: quant.policy.NodeQuant} —
    # covers EVERY conv node when a QuantPolicy planned this graph
    # (int8 nodes carry their scale source, fp nodes the fallback
    # reason); empty on fp plans
    quant: Dict[str, object] = dataclasses.field(default_factory=dict)
    # per-conv-node jitted executables, shared by warmup() and run() so
    # the warmup compile sweep is the same program inference reuses
    _jitted: Dict[str, Callable] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    @property
    def node_plans(self) -> Tuple[ConvPlan, ...]:
        """Conv-node plans in graph order (chain-era read surface)."""
        return tuple(self.conv_plans[n.name] for n in self.graph.conv_nodes)

    def _node_fn(self, name: str) -> Callable:
        fn = self._jitted.get(name)
        if fn is None:
            fn = jax.jit(self.conv_plans[name])
            self._jitted[name] = fn
        return fn

    def explain(self) -> str:
        """One aligned table for the whole network (every IR node):
        geometry, dtype, and executor provenance (which registry entry
        won and why — forced / measured / heuristic / cost)."""
        lines = [f"GraphPlan[{self.source}] backend={self.backend} "
                 f"sig={self.graph.signature()} nodes={len(self.graph)}"]
        for node in self.graph.nodes:
            if isinstance(node, ConvOp):
                p = self.conv_plans[node.name]
                s = p.spec
                n, h, w, c = s.in_shape
                kh, kw, _, m = s.filter_shape
                grp = f" g{s.groups}" if s.groups != 1 else ""
                cfg = (f" cfg[{p.config_source}]={p.config.key()}"
                       if p.config else "")
                fz = ""
                prov = self.fused.get(node.name)
                if prov:
                    kind, _, consumed = prov.partition(":")
                    fz = f" fused[{kind}]={consumed}"
                qz = ""
                nq = self.quant.get(node.name)
                if nq is not None:
                    qz = f" quant[{nq.label()}]"
                lines.append(
                    f"  {node.name:>8s}  {h:>3d}x{w:<3d} c{c:<4d} {kh}x{kw}/"
                    f"{s.stride[0]}{grp} m{m:<4d} {s.dtype:>9s} -> "
                    f"{p.algorithm:24s} [{p.source}]{cfg}{fz}{qz} {p.reason}")
            else:
                out = self.graph.shapes[node.name]
                lines.append(f"  {node.name:>8s}  {node.descriptor():50s} "
                             f"-> {out}")
        return "\n".join(lines)

    # -- execution -------------------------------------------------------
    def _named_params(self, params) -> Mapping[str, Mapping]:
        """Accept name-keyed params, or the chain-era list of (w, b)
        pairs assigned to conv nodes in graph order."""
        if isinstance(params, Mapping):
            return params
        convs = self.graph.conv_nodes
        pairs = list(params)
        if len(pairs) != len(convs):
            raise ValueError(f"graph has {len(convs)} conv nodes but got "
                             f"{len(pairs)} weight pairs")
        named = {}
        for node, (w, b) in zip(convs, pairs):
            named[node.name] = ({"w": w} if b is None
                                else {"w": w, "b": b})
        return named

    def _node_params(self, params: Mapping, node: OpSpec,
                     wants_bias: bool) -> Mapping:
        """One node's param dict, with errors that name the node instead
        of a bare KeyError from inside the DAG walk."""
        p = params.get(node.name)
        if p is None or "w" not in p:
            raise ValueError(
                f"params missing {'entry' if p is None else 'weight'} for "
                f"{node.op} node {node.name!r} (param keys: "
                f"{sorted(params)})")
        if wants_bias and "b" not in p:
            raise ValueError(f"{node.op} node {node.name!r} wants a bias "
                             f"but params carry none")
        return p

    def run(self, x, params, observe: Optional[Callable] = None):
        """Execute the DAG on ``x``.

        ``params``: ``{node_name: {"w": ..., "b": ...}}`` for conv and
        dense nodes (``b`` only where the node wants one), or — for
        graphs lowered from ``ConvGraph.chain`` — the legacy list of
        one ``(w, bias)`` pair per conv node in graph order.  No plan()
        resolution happens here — the program was resolved up front.

        ``observe``, when given, is called as ``observe(name, value)``
        with every conv node's INPUT activation (a concrete array —
        only the per-node executables are jitted, not the DAG walk);
        the calibration collector rides this hook.
        """
        params = self._named_params(params)
        from repro.kernels import ops
        values = {self.graph.input_name: x}
        for node in self.graph.nodes:
            ins = [values[e] for e in node.inputs]
            if isinstance(node, ConvOp):
                if observe is not None:
                    observe(node.name, ins[0])
                p = self._node_params(params, node, node.spec.has_bias)
                a = ins[1] if node.spec.fused_add != "none" else None
                y = self._node_fn(node.name)(
                    ins[0], p["w"], p["b"] if node.spec.has_bias else None, a)
            elif isinstance(node, PoolOp):
                y = ops.pool2d(ins[0], node.kind, node.window,
                               node.stride, node.padding)
            elif isinstance(node, AddOp):
                y = ins[0]
                for other in ins[1:]:
                    y = y + other
                if node.activation == "relu":
                    y = jax.nn.relu(y)
            elif isinstance(node, ConcatOp):
                y = jnp.concatenate(ins, axis=-1)
            elif isinstance(node, GapOp):
                y = ins[0].mean(axis=(1, 2))
            elif isinstance(node, DenseOp):
                p = self._node_params(params, node, node.bias)
                y = ins[0] @ p["w"]
                if node.bias:
                    y = y + p["b"]
            else:
                raise TypeError(f"unknown IR node type {type(node)}")
            values[node.name] = y
        return values[self.graph.output]

    def _attach_quant(self) -> None:
        """Re-attach the quantization payload (calibrated activation
        scale) to int8 node plans — needed after any re-resolution,
        since plan() knows nothing of calibration."""
        from repro.quant.policy import QuantInfo
        for name, nq in self.quant.items():
            if getattr(nq, "quantized", False) and name in self.conv_plans:
                self.conv_plans[name] = dataclasses.replace(
                    self.conv_plans[name],
                    quant=QuantInfo(nq.x_scale, nq.source))

    # -- warmup / autotune ----------------------------------------------
    def warmup(self, *, measure: bool = False,
               tune: Optional[str] = None, repeats: int = 3,
               calibrate: Optional[object] = None) -> Dict:
        """Compile (and optionally measure-autotune) every conv node in
        one sweep.

        ``tune="algo"`` runs the exhaustive per-node executor timing
        sweep (``autotune.tune_spec`` with the node's epilogue and
        groups threaded through); ``tune="full"`` then sweeps each
        winner's candidate *launch configs* (VMEM-pruned before timing).
        Either re-resolves each conv node against the freshly persisted
        winners and re-persists the graph-level entry — after which the
        plan serves inference with zero further plan() resolutions and
        zero re-measurement.  ``measure=True`` is the back-compat
        spelling of ``tune="algo"``.

        ``calibrate`` takes a ``quant.Calibrator`` (sample batch +
        params + observer choice): the plan runs over the batch first,
        recording every conv node's input activation range into the
        persisted ``calibration.json`` — the scales a later
        ``QuantPolicy``-planned graph quantizes with (DESIGN.md §13).

        Returns ``{"nodes": [...], "total_ms": float}`` with one
        algorithm/config/source/compile-time row per conv node (plus a
        ``"calibration"`` entry map when ``calibrate`` ran).
        """
        from repro.core import autotune
        if measure and tune is None:
            tune = "algo"
        t_start = time.perf_counter()
        calib_entries = None
        if calibrate is not None:
            calib_entries = calibrate.collect(self)
        if tune is not None:
            # tune-mode and backend-mismatch validation live in
            # tune_spec (one home), which raises before any node is
            # measured
            for node in self.graph.conv_nodes:
                autotune.tune_spec(node.spec, tune=tune,
                                   backend=self.backend, repeats=repeats)
            if tune == "full" and self.base_graph is not None:
                # tune="full" measured each fused spec against its
                # unfused decomposition (autotune.measure_fusion); re-run
                # the pass from the pre-fusion IR so losing rewrites are
                # dropped — and previously vetoed ones re-admitted
                refused, fmap = fuse_graph(self.base_graph, self.backend)
                if refused.signature() != self.graph.signature():
                    old = {n.name: n.spec for n in self.graph.conv_nodes}
                    self.graph, self.fused = refused, fmap
                    for node in self.graph.conv_nodes:
                        if old.get(node.name) != node.spec:
                            autotune.tune_spec(node.spec, tune=tune,
                                               backend=self.backend,
                                               repeats=repeats)
            self.conv_plans = {n.name: plan(n.spec, backend=self.backend)
                               for n in self.graph.conv_nodes}
            self._attach_quant()        # re-resolution dropped the scales
            self._jitted.clear()        # stale traces must not serve on
            _persist(self.base_graph or self.graph, self.backend,
                     self.conv_plans, alias=self.graph)
        rows = []
        for node in self.graph.conv_nodes:
            p = self.conv_plans[node.name]
            s = p.spec
            dtype = jnp.dtype(s.dtype)
            x = jnp.zeros(s.in_shape, dtype)
            w = jnp.zeros(s.filter_shape, dtype)
            b = jnp.zeros((s.filter_shape[3],), dtype) if s.has_bias else None
            a = (jnp.zeros(s.out_shape, dtype)
                 if s.fused_add != "none" else None)
            t0 = time.perf_counter()
            self._node_fn(node.name)(x, w, b, a).block_until_ready()
            rows.append({"node": node.name, "key": s.key(),
                         "algorithm": p.algorithm, "source": p.source,
                         "config": (p.config.as_dict() if p.config else {}),
                         "config_source": p.config_source,
                         "compile_ms": (time.perf_counter() - t0) * 1e3})
        out = {"nodes": rows,
               "total_ms": (time.perf_counter() - t_start) * 1e3}
        if calib_entries is not None:
            out["calibration"] = calib_entries
        return out


# ---------------------------------------------------------------------------
# resolution + persisted graph-level cache

def plan_graph(graph: GraphLike, *, backend: Optional[str] = None,
               force: Optional[str] = None,
               use_cache: bool = True, fuse: bool = True,
               quant: Optional[object] = None) -> GraphPlan:
    """Resolve a whole-network plan once.

    Accepts the IR (``Graph``) or the compatibility chain
    (``ConvGraph``, lowered via ``to_ir``).  A ``quant`` policy
    (``quant.QuantPolicy``) runs the int8 quantize pass over the IR
    first — eligible conv nodes' specs flip to int8 (DESIGN.md §13) —
    so everything downstream (fusion, cache keys, autotune) sees the
    quantized graph and is dtype-distinct by construction.  The
    cross-layer fusion pass (``fuse_graph``) rewrites the IR next —
    ``fuse=False`` is the escape hatch serving the unfused program.
    Forced plans bypass the persisted cache in both directions (they
    are a debugging/benchmark tool, not a deployment choice).
    Otherwise a persisted entry keyed by backend + the PRE-fusion graph
    signature (so callers address the cache by the graph they wrote,
    not the pass's output) reconstructs the program with zero per-node
    plan() resolutions; entries that are unversioned, carry a foreign
    schema, or name unknown / no-longer-supported algorithms are
    dropped and re-resolved.
    """
    ir = _as_ir(graph)
    backend = backend or jax.default_backend()
    qprov: Dict[str, object] = {}
    qinfos: Dict[str, object] = {}
    if quant is not None:
        from repro.quant.policy import quantize_graph
        ir, qprov, qinfos = quantize_graph(ir, quant, backend)
    fmap: Dict[str, str] = {}
    base = ir if fuse else None
    prog = ir
    if fuse:
        prog, fmap = fuse_graph(ir, backend)

    def _attach(plans: Dict[str, ConvPlan]) -> Dict[str, ConvPlan]:
        for name, qi in qinfos.items():
            if name in plans:
                plans[name] = dataclasses.replace(plans[name], quant=qi)
        return plans

    if force is not None:
        plans = {n.name: plan(n.spec, force=force, backend=backend)
                 for n in prog.conv_nodes}
        return GraphPlan(prog, _attach(plans), backend, "forced",
                         fused=fmap, base_graph=base, quant=qprov)
    if use_cache:
        cached = _plans_from_cache(prog, backend, key_graph=ir)
        if cached is not None:
            return GraphPlan(prog, _attach(cached), backend, "graph_cache",
                             fused=fmap, base_graph=base, quant=qprov)
    plans = {n.name: plan(n.spec, backend=backend) for n in prog.conv_nodes}
    if use_cache:       # use_cache=False means no cache interaction AT ALL
        _persist(ir, backend, plans, alias=prog)
    return GraphPlan(prog, _attach(plans), backend, "resolved",
                     fused=fmap, base_graph=base, quant=qprov)


def _graph_key(graph: GraphLike, backend: str) -> str:
    return f"{backend}/{graph.signature()}"


def _persist(graph: Graph, backend: str, plans: Mapping[str, ConvPlan],
             alias: Optional[Graph] = None) -> None:
    # ``graph`` is the addressing identity (the pre-fusion IR); when the
    # fusion pass rewrote it, ``alias`` is the fused program, which gets
    # the same entry under its own signature so callers holding either
    # graph can find it (reads go through the pre-fusion key)
    entry = {"schema": GRAPH_SCHEMA,
             "algorithms": {name: p.algorithm
                            for name, p in plans.items()}}
    _STORE.put(_graph_key(graph, backend), entry)
    if alias is not None and alias.signature() != graph.signature():
        _STORE.put(_graph_key(alias, backend), entry)


def _plans_from_cache(graph: Graph, backend: str,
                      key_graph: Optional[Graph] = None
                      ) -> Optional[Dict[str, ConvPlan]]:
    # ``graph`` is the (possibly fused) program whose conv specs the
    # entry must satisfy; ``key_graph`` is the pre-fusion IR the entry
    # is addressed by (fusion keeps conv node NAMES stable, so one entry
    # serves both the fused and unfused program of the same source IR)
    from repro.core import autotune, executors
    entry = _STORE.get(_graph_key(key_graph or graph, backend))
    if not isinstance(entry, dict):
        return None
    if entry.get("schema") != GRAPH_SCHEMA:
        return None       # unversioned / foreign-schema entry: never decode
    algos = entry.get("algorithms")
    conv_nodes = graph.conv_nodes
    if (not isinstance(algos, dict)
            or set(algos) != {n.name for n in conv_nodes}):
        return None
    plans: Dict[str, ConvPlan] = {}
    for node in conv_nodes:
        algo = algos[node.name]
        spec = node.spec
        if not executors.capable(algo, spec):
            return None                 # stale entry: caller re-resolves
        # a measured winner recorded since this entry was persisted must
        # win (plan()'s measured > heuristic precedence survives the
        # graph layer): treat the entry as stale and re-resolve
        measured = autotune.cached_best(spec, backend)
        if (measured is not None and measured != algo
                and executors.capable(measured, spec)):
            return None
        # launch configs are per-spec state (autotune.json), not part of
        # the graph entry: re-resolve so a measured config recorded
        # since — or one gone stale — is honored without re-measurement
        cfg, cfg_src = resolve_config(spec, algo, backend)
        plans[node.name] = ConvPlan(spec, algo, "graph_cache",
                                    "persisted graph-level plan", backend,
                                    config=cfg, config_source=cfg_src)
    return plans
