"""Measured per-layer algorithm selection, persisted across processes.

Mirrors the deployment behaviour the paper relies on ("most frameworks
automatically select the best-performing convolution algorithm for each
convolutional layer"):

  * heuristic mode — the registered executors' region claims
    (``executors.negotiate``, the paper's measured regions);
    ``select_algorithm`` is the back-compat shape-tuple wrapper.
  * measured mode — ``measure_algorithm`` times every viable candidate
    (compiled, synced) and records the winner keyed by
    ``(backend, ConvSpec.key())`` in a JSON cache under
    ``$REPRO_CACHE_DIR`` (default ``~/.cache/repro``), so one process's
    measurement sweep pays for every later process.  ``plan()`` consults
    this cache before falling back to the heuristic, and
    ``graph.GraphPlan.warmup(measure=True)`` sweeps a whole network
    through it in one pass.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import numpy as np

from repro.core.convspec import ConvPlan, ConvSpec, heuristic_algorithm
from repro.core.plancache import JsonCache

_STORE = JsonCache("autotune.json")


def _key(spec: ConvSpec, backend: str) -> str:
    # the epilogue rides whatever algorithm wins — measurements taken
    # without it must serve the bias/ReLU-fused specs conv_block builds,
    # so the cache key is epilogue-insensitive
    if spec.epilogue != "none":
        spec = dataclasses.replace(spec, epilogue="none")
    return f"{backend}/{spec.key()}"


def cached_best(spec: ConvSpec, backend: Optional[str] = None) -> Optional[str]:
    """Persisted measured winner for this spec on this backend, if any."""
    return _STORE.get(_key(spec, backend or jax.default_backend()))


def record_best(spec: ConvSpec, backend: str, algorithm: str) -> None:
    _STORE.put(_key(spec, backend), algorithm)


def clear_cache() -> None:
    """Drop the in-memory mirror (tests); the JSON file is untouched."""
    _STORE.clear()


# ---------------------------------------------------------------------------
# public API

def select_algorithm(x_shape, w_shape, stride=1) -> str:
    """Heuristic choice for a configuration (paper regions; see
    convspec.heuristic_algorithm for the region map)."""
    spec = ConvSpec(tuple(map(int, x_shape)), tuple(map(int, w_shape)),
                    (stride, stride) if isinstance(stride, int)
                    else tuple(stride))
    return heuristic_algorithm(spec, jax.default_backend())[0]


def default_candidates(spec: ConvSpec) -> Sequence[str]:
    """Every registered executor that can execute ``spec`` exactly —
    including the Pallas kernels this repo exists to showcase."""
    from repro.core import executors
    return executors.supporting(spec)


def measure_algorithm(x, w, stride=1, padding="same", repeats=3,
                      candidates: Optional[Sequence[str]] = None,
                      bias=None, activation: Optional[str] = None,
                      groups: int = 1) -> str:
    """Time every viable candidate (compiled, synced), persist the winner.

    The cuDNN-style exhaustive search the paper used for its baselines;
    ``plan()`` serves the recorded winner to every later process.

    ``candidates=None`` means every registered executor filtered by its
    declared capabilities (dtype included) — so the measured mode can
    pick the Pallas kernels, not just the XLA family, and a bf16 spec
    only times executors that declare bf16.  ``bias``/``activation``
    ride into the timed executions, so fused-epilogue paths are measured
    exactly as they deploy (epilogue in-kernel on the fused Pallas path,
    XLA ops elsewhere); the persisted key stays epilogue-insensitive
    (but dtype-distinct: ConvSpec.key() carries the dtype).
    """
    from repro.core import executors
    spec = ConvSpec.for_conv(x, w, stride, padding, bias=bias,
                             activation=activation, groups=groups)
    backend = jax.default_backend()
    hit = cached_best(spec, backend)
    # a persisted winner only short-circuits the sweep while it is still
    # a registered, capable executor — a stale entry (unregistered
    # plugin, tightened VMEM budget) re-measures and gets overwritten
    if hit is not None and executors.capable(hit, spec):
        return hit
    if candidates is None:
        candidates = default_candidates(spec)
    best, best_t = None, float("inf")
    for name in candidates:
        # unknown or incapable candidates are skipped, not fatal: an
        # explicit candidate list may name a plugin this process never
        # registered, and the sweep should still time the rest
        if not executors.capable(name, spec):
            continue
        # time through a ConvPlan so the epilogue runs as deployed
        p = ConvPlan(spec, name, "candidate", "autotune timing", backend)
        fn = jax.jit(p)
        try:
            fn(x, w, bias).block_until_ready()    # compile + warm
            ts = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                fn(x, w, bias).block_until_ready()
                ts.append(time.perf_counter() - t0)
            t = float(np.median(ts))
        except Exception:
            continue
        if t < best_t:
            best, best_t = name, t
    if best is None:
        # nothing timed successfully: don't persist a fake "measured"
        # winner — leave the planner on its heuristic/cost tiers and
        # report what negotiation would run
        return executors.negotiate(spec, backend)[0]
    record_best(spec, backend, best)
    return best
