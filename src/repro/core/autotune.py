"""Measured per-layer algorithm selection, persisted across processes.

Mirrors the deployment behaviour the paper relies on ("most frameworks
automatically select the best-performing convolution algorithm for each
convolutional layer"):

  * heuristic mode — ``convspec.heuristic_algorithm`` encodes the
    paper's measured regions; ``select_algorithm`` is the back-compat
    shape-tuple wrapper.
  * measured mode — ``measure_algorithm`` times every viable candidate
    (compiled, synced) and records the winner keyed by
    ``(backend, ConvSpec.key())`` in a JSON cache under
    ``$REPRO_CACHE_DIR`` (default ``~/.cache/repro``), so one process's
    measurement sweep pays for every later process.  ``plan()`` consults
    this cache before falling back to the heuristic.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import time
from pathlib import Path
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.convspec import ConvSpec, heuristic_algorithm, supports

# in-memory mirror of the persisted JSON: {cache_key: algorithm}
_CACHE: Dict[str, str] = {}
_CACHE_PATH: Optional[Path] = None     # path _CACHE was loaded from


def _cache_path() -> Path:
    d = os.environ.get("REPRO_CACHE_DIR",
                       os.path.join(os.path.expanduser("~"), ".cache",
                                    "repro"))
    return Path(d) / "autotune.json"


def _ensure_loaded() -> None:
    global _CACHE, _CACHE_PATH
    path = _cache_path()
    if path == _CACHE_PATH:
        return
    _CACHE_PATH = path
    _CACHE = {}
    try:
        _CACHE.update(json.loads(path.read_text()))
    except (OSError, ValueError):
        pass                            # no/corrupt cache: start empty


def _persist() -> None:
    path = _cache_path()
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        # merge what concurrent processes persisted since our load, so a
        # stale snapshot never clobbers their measurements
        try:
            merged = json.loads(path.read_text())
        except (OSError, ValueError):
            merged = {}
        merged.update(_CACHE)
        _CACHE.update(merged)
        tmp = path.with_suffix(".tmp")
        tmp.write_text(json.dumps(merged, indent=0, sort_keys=True))
        os.replace(tmp, path)           # atomic: readers never see a torn file
    except OSError:
        pass                            # read-only FS: stay in-memory only


def _key(spec: ConvSpec, backend: str) -> str:
    # the epilogue rides whatever algorithm wins — measurements taken
    # without it must serve the bias/ReLU-fused specs conv_block builds,
    # so the cache key is epilogue-insensitive
    if spec.epilogue != "none":
        spec = dataclasses.replace(spec, epilogue="none")
    return f"{backend}/{spec.key()}"


def cached_best(spec: ConvSpec, backend: Optional[str] = None) -> Optional[str]:
    """Persisted measured winner for this spec on this backend, if any."""
    _ensure_loaded()
    return _CACHE.get(_key(spec, backend or jax.default_backend()))


def record_best(spec: ConvSpec, backend: str, algorithm: str) -> None:
    _ensure_loaded()
    _CACHE[_key(spec, backend)] = algorithm
    _persist()


def clear_cache() -> None:
    """Drop the in-memory mirror (tests); the JSON file is untouched."""
    global _CACHE_PATH
    _CACHE_PATH = None


# ---------------------------------------------------------------------------
# public API

def select_algorithm(x_shape, w_shape, stride=1) -> str:
    """Heuristic choice for a configuration (paper regions; see
    convspec.heuristic_algorithm for the region map)."""
    spec = ConvSpec(tuple(map(int, x_shape)), tuple(map(int, w_shape)),
                    (stride, stride) if isinstance(stride, int)
                    else tuple(stride))
    return heuristic_algorithm(spec, jax.default_backend())[0]


def measure_algorithm(x, w, stride=1, padding="same", repeats=3,
                      candidates=("lax", "im2col", "winograd",
                                  "cuconv_two_stage", "cuconv")) -> str:
    """Time every viable candidate (compiled, synced), persist the winner.

    The cuDNN-style exhaustive search the paper used for its baselines;
    ``plan()`` serves the recorded winner to every later process.
    """
    from repro.core.cuconv import ALGORITHMS
    spec = ConvSpec.for_conv(x, w, stride, padding)
    backend = jax.default_backend()
    hit = cached_best(spec, backend)
    if hit is not None:
        return hit
    best, best_t = None, float("inf")
    for name in candidates:
        if not supports(name, spec)[0]:
            continue
        fn = jax.jit(functools.partial(ALGORITHMS[name], stride=stride,
                                       padding=padding))
        try:
            fn(x, w).block_until_ready()          # compile + warm
            ts = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                fn(x, w).block_until_ready()
                ts.append(time.perf_counter() - t0)
            t = float(np.median(ts))
        except Exception:
            continue
        if t < best_t:
            best, best_t = name, t
    best = best or "lax"
    record_best(spec, backend, best)
    return best
