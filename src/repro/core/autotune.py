"""Measured per-layer algorithm + launch-config selection, persisted
across processes.

Mirrors the deployment behaviour the paper relies on ("most frameworks
automatically select the best-performing convolution algorithm for each
convolutional layer") — and the paper's own per-configuration *launch
selection* (thread-block geometry per convolution configuration, the
lever maxDNN showed is worth large factors on its own):

  * heuristic mode — the registered executors' region claims
    (``executors.negotiate``, the paper's measured regions);
    ``select_algorithm`` is the back-compat shape-tuple wrapper.
  * measured mode — ``measure_algorithm`` times every viable candidate
    executor (compiled, synced); ``measure_config`` then sweeps the
    winner's candidate *launch configs* (tile sizes, rows-per-step —
    ``Executor.configs``, VMEM-pruned via ``config_supports`` before
    anything is timed).  ``tune_spec`` is the one entry point
    ``plan(tune=...)`` and ``GraphPlan.warmup(tune=...)`` share.

Winners are persisted keyed by ``(backend, ConvSpec.key())`` in a JSON
cache under ``$REPRO_CACHE_DIR`` (default ``~/.cache/repro``) as
schema-versioned entries::

    {"schema": 2, "algorithm": "cuconv_pallas",   # measured winner (or null)
     "configs": {"cuconv_pallas": {"tm": 256, "rows": 4}}}

so one process's measurement sweep pays for every later process.
``configs`` maps *per algorithm*: tuning a pinned/forced executor's
launch configs records under that executor's key without overwriting
the genuinely measured ``algorithm`` winner (and a config is only ever
served back for the executor it was measured with).  Unversioned
entries (the pre-config era persisted bare algorithm strings) and
foreign-schema entries are dropped on read — never misdecoded into the
``(algorithm, config)`` shape — and re-measured.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.convspec import ConvPlan, ConvSpec, heuristic_algorithm
from repro.core.plancache import JsonCache

#: persisted-entry schema.  v1 was the bare algorithm string (implicitly
#: unversioned); v2 is {"schema": 2, "algorithm": str[, "config": {...}]}.
AUTOTUNE_SCHEMA = 2

_STORE = JsonCache("autotune.json")

#: observable measurement effort — tests assert the replay-from-cache
#: path performs ZERO re-measurement against these counters
MEASURE_STATS = {"algo_sweeps": 0, "config_sweeps": 0, "fusion_sweeps": 0,
                 "timed_calls": 0}


def reset_measure_stats() -> dict:
    """Zero the measurement counters; returns the discarded counts."""
    old = dict(MEASURE_STATS)
    for k in MEASURE_STATS:
        MEASURE_STATS[k] = 0
    return old


def _key(spec: ConvSpec, backend: str) -> str:
    # the epilogue rides whatever algorithm wins — measurements taken
    # without it must serve the bias/ReLU-fused specs conv_block builds,
    # so the cache key is epilogue-insensitive
    if spec.epilogue != "none":
        spec = dataclasses.replace(spec, epilogue="none")
    return f"{backend}/{spec.key()}"


def _entry(spec: ConvSpec, backend: Optional[str]) -> Optional[dict]:
    """The persisted entry for this spec, schema-gated: unversioned
    (pre-config bare strings) or foreign-schema values are dropped."""
    e = _STORE.get(_key(spec, backend or jax.default_backend()))
    if not isinstance(e, dict) or e.get("schema") != AUTOTUNE_SCHEMA:
        return None
    algo = e.get("algorithm")
    if algo is not None and not isinstance(algo, str):
        return None         # algorithm may be null: config-only entries
    return e


def cached_best(spec: ConvSpec, backend: Optional[str] = None
                ) -> Optional[str]:
    """Persisted measured winner for this spec on this backend, if any."""
    e = _entry(spec, backend)
    return None if e is None else e.get("algorithm")


def cached_config(spec: ConvSpec, backend: Optional[str] = None,
                  algorithm: Optional[str] = None):
    """Persisted measured launch config (``executors.LaunchConfig``) for
    ``algorithm`` on this spec (default: the entry's measured winner),
    or None.

    Configs are stored per algorithm — one tuned for an executor is
    only ever served back for that executor.  Validity against the
    executor's *current* declarations is the caller's job
    (``convspec.resolve_config`` gates through ``config_supports``).
    """
    from repro.core.executors import LaunchConfig
    e = _entry(spec, backend)
    if e is None:
        return None
    if algorithm is None:
        algorithm = e.get("algorithm")
        if algorithm is None:
            return None
    cfgs = e.get("configs")
    cfg = cfgs.get(algorithm) if isinstance(cfgs, dict) else None
    if not isinstance(cfg, dict):
        return None
    try:
        return LaunchConfig.of(cfg)
    except ValueError:
        return None                 # malformed dims: drop, re-measure


def _merged_entry(spec: ConvSpec, backend: str) -> dict:
    e = _entry(spec, backend)
    if e is None:
        e = {"schema": AUTOTUNE_SCHEMA, "algorithm": None, "configs": {}}
    if not isinstance(e.get("configs"), dict):
        e["configs"] = {}
    return e


def record_best(spec: ConvSpec, backend: str, algorithm: str,
                config=None) -> None:
    """Persist a measured winner (schema-versioned).  ``config``, if
    given, records under the winner's per-algorithm config slot."""
    entry = _merged_entry(spec, backend)
    entry["algorithm"] = algorithm
    if config:
        from repro.core.executors import LaunchConfig
        entry["configs"][algorithm] = LaunchConfig.of(config).as_dict()
    _STORE.put(_key(spec, backend), entry)


def record_config(spec: ConvSpec, backend: str, algorithm: str,
                  config) -> None:
    """Persist a measured launch config for ``algorithm`` WITHOUT
    touching the entry's measured-winner field — tuning a pinned/forced
    executor must not make later unforced plans serve it as the
    'measured' algorithm it never was."""
    from repro.core.executors import LaunchConfig
    entry = _merged_entry(spec, backend)
    entry["configs"][algorithm] = LaunchConfig.of(config).as_dict()
    _STORE.put(_key(spec, backend), entry)


def clear_cache() -> None:
    """Drop the in-memory mirror (tests); the JSON file is untouched."""
    _STORE.clear()


# ---------------------------------------------------------------------------
# public API

def select_algorithm(x_shape, w_shape, stride=1) -> str:
    """Heuristic choice for a configuration (paper regions; see
    convspec.heuristic_algorithm for the region map)."""
    spec = ConvSpec(tuple(map(int, x_shape)), tuple(map(int, w_shape)),
                    (stride, stride) if isinstance(stride, int)
                    else tuple(stride))
    return heuristic_algorithm(spec, jax.default_backend())[0]


def default_candidates(spec: ConvSpec) -> Sequence[str]:
    """Every registered executor that can execute ``spec`` exactly —
    including the Pallas kernels this repo exists to showcase."""
    from repro.core import executors
    return executors.supporting(spec)


def _time_plan(p, x, w, bias, repeats: int, addend=None) -> float:
    """Median wall time of a jitted plan execution (compiled, synced)."""
    fn = jax.jit(p)
    args = (x, w, bias) if addend is None else (x, w, bias, addend)
    fn(*args).block_until_ready()    # compile + warm
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        ts.append(time.perf_counter() - t0)
    MEASURE_STATS["timed_calls"] += 1 + repeats
    return float(np.median(ts))


def _fused_operands(spec: ConvSpec):
    """Synthesized (x, w, bias, addend) for timing a bare spec."""
    dtype = jnp.dtype(spec.dtype)
    x = jnp.zeros(spec.in_shape, dtype)
    w = jnp.zeros(spec.filter_shape, dtype)
    b = jnp.zeros((spec.filter_shape[3],), dtype) if spec.has_bias else None
    a = (jnp.zeros(spec.out_shape, dtype)
         if spec.fused_add != "none" else None)
    return x, w, b, a


def measure_algorithm(x, w, stride=1, padding="same", repeats=3,
                      candidates: Optional[Sequence[str]] = None,
                      bias=None, activation: Optional[str] = None,
                      groups: int = 1,
                      spec: Optional[ConvSpec] = None) -> str:
    """Time every viable candidate (compiled, synced), persist the winner.

    The cuDNN-style exhaustive search the paper used for its baselines;
    ``plan()`` serves the recorded winner to every later process.

    ``candidates=None`` means every registered executor filtered by its
    declared capabilities (dtype included) — so the measured mode can
    pick the Pallas kernels, not just the XLA family, and a bf16 spec
    only times executors that declare bf16.  ``bias``/``activation``
    ride into the timed executions, so fused-epilogue paths are measured
    exactly as they deploy (epilogue in-kernel on the fused Pallas path,
    XLA ops elsewhere); the persisted key stays epilogue-insensitive
    (but dtype-distinct: ConvSpec.key() carries the dtype).  Each
    executor is timed under its model-chosen ``default_config`` (the
    per-config sweep is ``measure_config``).  ``spec`` overrides the
    operand-derived descriptor — the only way a *fused* spec (cross-
    layer add/pool fields; they cannot be inferred from operands) is
    swept as itself.
    """
    from repro.core import executors
    if spec is None:
        spec = ConvSpec.for_conv(x, w, stride, padding, bias=bias,
                                 activation=activation, groups=groups)
    addend = (jnp.zeros(spec.out_shape, jnp.dtype(spec.dtype))
              if spec.fused_add != "none" else None)
    backend = jax.default_backend()
    hit = cached_best(spec, backend)
    # a persisted winner only short-circuits the sweep while it is still
    # a registered, capable executor — a stale entry (unregistered
    # plugin, tightened VMEM budget) re-measures and gets overwritten
    if hit is not None and executors.capable(hit, spec):
        return hit
    if candidates is None:
        candidates = default_candidates(spec)
    MEASURE_STATS["algo_sweeps"] += 1
    best, best_t = None, float("inf")
    for name in candidates:
        # unknown or incapable candidates are skipped, not fatal: an
        # explicit candidate list may name a plugin this process never
        # registered, and the sweep should still time the rest
        if not executors.capable(name, spec):
            continue
        # time through a ConvPlan so the epilogue runs as deployed;
        # default_config rides inside the guard so one candidate's
        # broken tuning declarations degrade the sweep, not crash it
        try:
            p = ConvPlan(spec, name, "candidate", "autotune timing",
                         backend,
                         config=executors.get(name).default_config(spec))
            t = _time_plan(p, x, w, bias, repeats, addend)
        except Exception:
            continue
        if t < best_t:
            best, best_t = name, t
    if best is None:
        # nothing timed successfully: don't persist a fake "measured"
        # winner — leave the planner on its heuristic/cost tiers and
        # report what negotiation would run
        return executors.negotiate(spec, backend)[0]
    record_best(spec, backend, best)
    return best


def measure_config(x, w, stride=1, padding="same", repeats=3,
                   algorithm: Optional[str] = None,
                   candidates=None, bias=None,
                   activation: Optional[str] = None,
                   groups: int = 1,
                   spec: Optional[ConvSpec] = None) -> Tuple[str, object]:
    """Sweep an executor's candidate launch configs, persist the winner.

    ``algorithm=None`` tunes the spec's measured winner (else the
    negotiated choice).  Candidates default to the executor's declared
    ``configs(spec)``, pruned through ``config_supports`` (VMEM budget,
    geometry rules) BEFORE anything is timed.  The winning
    ``(algorithm, config)`` pair is persisted under the versioned
    schema; with default candidates a persisted, still-valid config
    short-circuits the sweep — replaying a tuned spec costs zero
    measurements.  An *explicit* ``candidates`` list is a request to
    measure exactly those configs: it is always timed (and its winner
    overwrites the persisted config).  Returns
    ``(algorithm, LaunchConfig)``.  ``spec`` overrides the operand-
    derived descriptor (fused cross-layer specs; see
    ``measure_algorithm``).
    """
    from repro.core import executors
    if spec is None:
        spec = ConvSpec.for_conv(x, w, stride, padding, bias=bias,
                                 activation=activation, groups=groups)
    addend = (jnp.zeros(spec.out_shape, jnp.dtype(spec.dtype))
              if spec.fused_add != "none" else None)
    backend = jax.default_backend()
    if algorithm is None:
        algorithm = cached_best(spec, backend)
        if algorithm is None or not executors.capable(algorithm, spec):
            algorithm = executors.negotiate(spec, backend)[0]
    ex = executors.get(algorithm)
    if not ex.supports(spec)[0]:
        # an explicitly named executor that cannot run the spec at all:
        # nothing to sweep (and nothing to persist — a timed config for
        # an incapable executor would be meaningless)
        return algorithm, ex.default_config(spec)
    if candidates is None:
        # default sweep: a persisted, still-valid config replays free
        hit = cached_config(spec, backend, algorithm)
        if hit is not None and ex.config_supports(spec, hit)[0]:
            return algorithm, hit
        candidates = ex.configs(spec)
    feasible = []
    for c in candidates:
        c = executors.LaunchConfig.of(c)
        if ex.config_supports(spec, c)[0] and c not in feasible:
            feasible.append(c)
    if not feasible or (len(feasible) == 1 and not feasible[0]):
        # untunable executor (or nothing survived pruning): nothing to
        # sweep, nothing to persist beyond the algorithm itself
        return algorithm, ex.default_config(spec)
    MEASURE_STATS["config_sweeps"] += 1
    best, best_t = None, float("inf")
    for cfg in feasible:
        p = ConvPlan(spec, algorithm, "candidate",
                     "autotune config timing", backend, config=cfg,
                     config_source="candidate")
        try:
            t = _time_plan(p, x, w, bias, repeats, addend)
        except Exception:
            continue
        if t < best_t:
            best, best_t = cfg, t
    if best is None:
        return algorithm, ex.default_config(spec)
    record_config(spec, backend, algorithm, best)
    return algorithm, best


def fusion_verdict(spec: ConvSpec, backend: Optional[str] = None
                   ) -> Optional[bool]:
    """Persisted fused-vs-unfused arbitration for a fused spec.

    True: the fused kernel measured at least as fast as its unfused
    decomposition; False: fusion measured slower (the graph pass keeps
    the nodes separate); None: never measured (the pass fuses on the
    cost model's word — fusion strictly removes HBM round trips).
    """
    e = _entry(spec, backend)
    if e is None or not isinstance(e.get("fusion"), dict):
        return None
    return bool(e["fusion"].get("wins", True))


def measure_fusion(spec: ConvSpec, backend: Optional[str] = None,
                   repeats: int = 3, force: bool = False
                   ) -> Optional[bool]:
    """Time a fused spec against its unfused decomposition and persist
    the verdict (``tune="full"`` arbitration, DESIGN.md §10).

    The unfused side runs the SAME conv plan the pre-fusion graph would
    have resolved, followed by the XLA add/ReLU or pool the consumed
    node would have executed — an apples-to-apples per-layer race.  The
    verdict persists under the fused spec's (fusion-distinct) cache key
    as ``{"fusion": {"wins": bool, "fused_us": ..., "unfused_us": ...}}``
    and replays free; ``force=True`` re-measures.  Returns the verdict,
    or None when timing failed (nothing is persisted then).
    """
    from repro.core import convspec
    from repro.kernels import ops
    if not spec.has_fusion:
        raise ValueError(f"spec {spec.key()} carries no fusion to measure")
    backend = backend or jax.default_backend()
    if not force:
        hit = fusion_verdict(spec, backend)
        if hit is not None:
            return hit
    MEASURE_STATS["fusion_sweeps"] += 1
    x, w, b, addend = _fused_operands(spec)
    fused_plan = convspec.plan(spec, backend=backend)
    base_plan = convspec.plan(spec.unfused(), backend=backend)
    if spec.fused_add != "none":
        post_relu = spec.fused_add == "add_relu"

        def unfused(x, w, bias=None, addend=None):
            y = base_plan(x, w, bias) + addend
            return jnp.maximum(y, 0) if post_relu else y
    else:
        kind, pkh, pkw, psh, psw, pph, ppw = spec.fused_pool

        def unfused(x, w, bias=None):
            return ops.pool2d(base_plan(x, w, bias), kind=kind,
                              window=(pkh, pkw), stride=(psh, psw),
                              padding=(pph, ppw))
    try:
        fused_t = _time_plan(fused_plan, x, w, b, repeats, addend)
        unfused_t = _time_plan(unfused, x, w, b, repeats, addend)
    except Exception:
        return None              # nothing timed: leave the verdict open
    wins = fused_t <= unfused_t
    entry = _merged_entry(spec, backend)
    entry["fusion"] = {"wins": wins,
                       "fused_us": round(fused_t * 1e6, 3),
                       "unfused_us": round(unfused_t * 1e6, 3)}
    _STORE.put(_key(spec, backend), entry)
    return wins


def tune_spec(spec: ConvSpec, *, tune: str = "algo",
              backend: Optional[str] = None, repeats: int = 3,
              algorithm: Optional[str] = None) -> Tuple[str, object]:
    """Measure a bare ConvSpec (operands synthesized from its shapes):
    the one tuning entry point ``plan(tune=...)``,
    ``GraphPlan.warmup(tune=...)`` and the serve engine share.

    ``tune="algo"`` runs the executor sweep — even when ``algorithm``
    pins the executor, so the sweep's winner is recorded for later
    *unforced* plans (the pin only decides what this plan serves).
    ``tune="full"`` then sweeps the candidate launch configs of the
    pinned executor (if any) or of the sweep's winner.  Returns
    ``(algorithm, LaunchConfig | None)``.
    """
    if tune not in ("algo", "full"):
        raise ValueError(f'tune must be "algo" or "full"; got {tune!r}')
    backend = backend or jax.default_backend()
    if backend != jax.default_backend():
        # timing on this process's backend and recording it under
        # another backend's key would silently discard the sweep
        raise ValueError(
            f"measured tuning must run on the target backend: asked for "
            f"{backend!r} but this process runs {jax.default_backend()!r}")
    x, w, b, _ = _fused_operands(spec)
    act = "relu" if spec.wants_relu else None
    kwargs = dict(stride=spec.stride, padding=spec.padding, repeats=repeats,
                  bias=b, activation=act, groups=spec.groups, spec=spec)
    if tune == "algo" or algorithm is None:
        best = measure_algorithm(x, w, **kwargs)
        if algorithm is None:
            algorithm = best
    if tune == "full":
        if spec.has_fusion:
            # fused-vs-unfused arbitration: the graph pass consults the
            # persisted verdict on its next rewrite of this spec
            measure_fusion(spec, backend=backend, repeats=repeats)
        return measure_config(x, w, algorithm=algorithm, **kwargs)
    return algorithm, None
