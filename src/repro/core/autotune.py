"""Per-layer convolution algorithm selection.

Mirrors the deployment behaviour the paper relies on ("most frameworks
automatically select the best-performing convolution algorithm for each
convolutional layer"): a heuristic mode encoding the paper's measured
regions, and a measured mode that times every candidate and caches the
winner per configuration — the cuDNN-style exhaustive search the paper
used for its baselines.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Tuple

import jax
import numpy as np

_MEASURED_CACHE: Dict[Tuple, str] = {}


def select_algorithm(x_shape, w_shape, stride=1) -> str:
    """Heuristic choice, encoding the paper's empirical regions (fig 5-7):

    - 1x1 filters: cuConv's best region (single GEMM, no stage 2);
    - small batch + small spatial: cuConv wins (its thread-level
      parallelism advantage on GPU; on TPU the grid fills cores even at
      batch 1);
    - large 3x3 workloads: the library algorithm (Winograd's region in the
      paper) keeps the edge.
    """
    n, h, w_sp, c = x_shape
    kh, kw, _, m = w_shape
    if stride != 1:
        return "lax"
    if kh == 1 and kw == 1:
        return "cuconv"
    if n == 1 or (h <= 14 and n <= 16):
        return "cuconv"
    if kh == 3 and kw == 3:
        return "winograd"     # Winograd-dominated region in the paper
    return "cuconv"


def measure_algorithm(x, w, stride=1, padding="same", repeats=3,
                      candidates=("lax", "im2col", "winograd",
                                  "cuconv_two_stage", "cuconv")) -> str:
    """Time every candidate (compiled, synced) and cache the winner."""
    from repro.core.cuconv import ALGORITHMS
    key = (x.shape, w.shape, stride, str(x.dtype))
    if key in _MEASURED_CACHE:
        return _MEASURED_CACHE[key]
    best, best_t = None, float("inf")
    for name in candidates:
        fn = jax.jit(functools.partial(ALGORITHMS[name], stride=stride,
                                       padding=padding))
        try:
            fn(x, w).block_until_ready()          # compile + warm
            ts = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                fn(x, w).block_until_ready()
                ts.append(time.perf_counter() - t0)
            t = float(np.median(ts))
        except Exception:
            continue
        if t < best_t:
            best, best_t = name, t
    _MEASURED_CACHE[key] = best or "lax"
    return _MEASURED_CACHE[key]
