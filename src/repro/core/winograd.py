"""Winograd F(2x2, 3x3) convolution — the paper's strongest competitor.

cuDNN's Winograd variants dominate the paper's 3x3 configurations
(fig. 6; "in around 40% of the cases the second highest performing
variant is at least 50% slower than one of the two Winograd variants"),
so a faithful baseline set needs a real Winograd, not just lax.conv.

Lavin & Gray 2015 minimal filtering: each 4x4 input tile (2x2 output,
overlap 2) is transformed with B^T d B, filters once with G g G^T, the
elementwise products accumulate over channels, and A^T m A produces the
2x2 output tile — 2.25x fewer multiplies than direct conv at the price
of the transforms, which is exactly the trade-off the paper discusses
(transform overhead dominates at small computational loads, cuConv's
winning region).

Pure-jnp implementation (stride 1, 3x3 filters; the tile-batched
elementwise product is a (tiles x C) @ (C x M) GEMM per of the 16 tile
positions — MXU-friendly on the TPU target).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

# F(2x2, 3x3) transform matrices (Lavin & Gray / Winograd 1980)
_BT = np.array([[1, 0, -1, 0],
                [0, 1, 1, 0],
                [0, -1, 1, 0],
                [0, 1, 0, -1]], np.float32)
_G = np.array([[1, 0, 0],
               [0.5, 0.5, 0.5],
               [0.5, -0.5, 0.5],
               [0, 0, 1]], np.float32)
_AT = np.array([[1, 1, 1, 0],
                [0, 1, -1, -1]], np.float32)


def transform_filters(w):
    """w: (3, 3, C, M) -> (4, 4, C, M): U = G g G^T per (C, M)."""
    G = jnp.asarray(_G)
    return jnp.einsum("ij,jkcm,lk->ilcm", G, w, G)


def conv_winograd(x, w, stride=1, padding="same"):
    """x: (N, H, W, C) NHWC; w: (3, 3, C, M); stride must be 1."""
    assert w.shape[0] == 3 and w.shape[1] == 3, "F(2x2,3x3) needs 3x3 filters"
    assert stride == 1, "Winograd baseline is stride-1 (as in the paper)"
    N, H, W, C = x.shape
    M = w.shape[3]
    if padding == "same":
        ph = pw = 1
    elif padding == "valid":
        ph = pw = 0
    else:
        ph, pw = (padding, padding) if isinstance(padding, int) else padding
    OH, OW = H + 2 * ph - 2, W + 2 * pw - 2

    # pad so output tiles of 2x2 cover OH x OW exactly
    th, tw = (OH + 1) // 2, (OW + 1) // 2
    Hp, Wp = 2 * th + 2, 2 * tw + 2
    xp = jnp.pad(x, ((0, 0), (ph, Hp - H - ph), (pw, Wp - W - pw), (0, 0)))

    # gather 4x4 input tiles with stride 2 (overlap 2): (N, th, tw, 4, 4, C)
    i_idx = (2 * jnp.arange(th))[:, None] + jnp.arange(4)[None, :]   # (th,4)
    j_idx = (2 * jnp.arange(tw))[:, None] + jnp.arange(4)[None, :]   # (tw,4)
    tiles = xp[:, i_idx][:, :, :, j_idx]            # (N, th, 4, tw, 4, C)
    tiles = tiles.transpose(0, 1, 3, 2, 4, 5)       # (N, th, tw, 4, 4, C)

    BT = jnp.asarray(_BT)
    V = jnp.einsum("ij,nhwjkc,lk->nhwilc", BT, tiles.astype(jnp.float32), BT)
    U = transform_filters(w.astype(jnp.float32))    # (4, 4, C, M)

    # elementwise product in the Winograd domain == 16 channel GEMMs
    Mdom = jnp.einsum("nhwijc,ijcm->nhwijm", V, U)  # (N, th, tw, 4, 4, M)

    AT = jnp.asarray(_AT)
    Y = jnp.einsum("ij,nhwjkm,lk->nhwilm", AT, Mdom, AT)  # (..., 2, 2, M)
    out = Y.transpose(0, 1, 3, 2, 4, 5).reshape(N, 2 * th, 2 * tw, M)
    return out[:, :OH, :OW, :].astype(x.dtype)
