"""Winograd F(m, 3) convolution — the paper's strongest competitor.

cuDNN's Winograd variants dominate the paper's 3x3 configurations
(fig. 6; "in around 40% of the cases the second highest performing
variant is at least 50% slower than one of the two Winograd variants"),
so a faithful baseline set needs a real Winograd, not just lax.conv.

Lavin & Gray 2015 minimal filtering: each (m+2)x(m+2) input tile
(m x m output, overlap 2) is transformed with B^T d B, filters once
with G g G^T, the elementwise products accumulate over channels, and
A^T m A produces the m x m output tile.  F(2x2,3x3) saves 2.25x
multiplies over direct conv, F(4x4,3x3) saves 4x, at the price of the
transforms — exactly the trade-off the paper discusses (transform
overhead dominates at small computational loads, cuConv's winning
region).  The F(4x4,3x3) transform constants are larger (the G rows
carry 1/24-scale entries against A^T rows up to 8), so its numeric
error is measurably bigger; tests/test_winograd.py pins both bounds.

This module owns the transform matrices — ``matrices(m)`` is the one
home both the pure-jnp path below and the tiled Pallas kernel
(kernels/winograd_pallas.py) read them from.

Pure-jnp implementation (stride 1, 3x3 filters; the tile-batched
elementwise product is a (tiles x C) @ (C x M) GEMM per of the (m+2)^2
tile positions — MXU-friendly on the TPU target).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

# F(2x2, 3x3) transform matrices (Lavin & Gray / Winograd 1980)
_BT = np.array([[1, 0, -1, 0],
                [0, 1, 1, 0],
                [0, -1, 1, 0],
                [0, 1, 0, -1]], np.float32)
_G = np.array([[1, 0, 0],
               [0.5, 0.5, 0.5],
               [0.5, -0.5, 0.5],
               [0, 0, 1]], np.float32)
_AT = np.array([[1, 1, 1, 0],
                [0, 1, -1, -1]], np.float32)

# F(4x4, 3x3) transform matrices (Lavin & Gray, the cuDNN winograd_4x4
# variant's points {0, ±1, ±2})
_BT4 = np.array([[4, 0, -5, 0, 1, 0],
                 [0, -4, -4, 1, 1, 0],
                 [0, 4, -4, -1, 1, 0],
                 [0, -2, -1, 2, 1, 0],
                 [0, 2, -1, -2, 1, 0],
                 [0, 4, 0, -5, 0, 1]], np.float32)
_G4 = np.array([[1 / 4, 0, 0],
                [-1 / 6, -1 / 6, -1 / 6],
                [-1 / 6, 1 / 6, -1 / 6],
                [1 / 24, 1 / 12, 1 / 6],
                [1 / 24, -1 / 12, 1 / 6],
                [0, 0, 1]], np.float32)
_AT4 = np.array([[1, 1, 1, 1, 1, 0],
                 [0, 1, -1, 2, -2, 0],
                 [0, 1, 1, 4, 4, 0],
                 [0, 1, -1, 8, -8, 1]], np.float32)

#: F(m, 3) variant -> (B^T, G, A^T) as numpy f32 constants
MATRICES = {2: (_BT, _G, _AT), 4: (_BT4, _G4, _AT4)}


def matrices(m: int):
    """``(B^T, G, A^T)`` for the F(m x m, 3 x 3) variant; m in {2, 4}."""
    try:
        return MATRICES[m]
    except KeyError:
        raise ValueError(f"Winograd F(m,3) variant must be one of "
                         f"{sorted(MATRICES)}; got m={m}") from None


def transform_filters(w, m: int = 2):
    """w: (3, 3, C, M) -> (m+2, m+2, C, M): U = G g G^T per (C, M)."""
    G = jnp.asarray(matrices(m)[1])
    return jnp.einsum("ij,jkcm,lk->ilcm", G, w, G)


def conv_winograd(x, w, stride=1, padding="same", m: int = 2):
    """x: (N, H, W, C) NHWC; w: (3, 3, C, M); stride must be 1."""
    assert w.shape[0] == 3 and w.shape[1] == 3, "F(m,3) needs 3x3 filters"
    assert stride == 1, "Winograd baseline is stride-1 (as in the paper)"
    BT, _, AT = (jnp.asarray(t) for t in matrices(m))
    a = m + 2                                   # input-tile edge
    N, H, W, C = x.shape
    M = w.shape[3]
    if padding == "same":
        ph = pw = 1
    elif padding == "valid":
        ph = pw = 0
    else:
        ph, pw = (padding, padding) if isinstance(padding, int) else padding
    OH, OW = H + 2 * ph - 2, W + 2 * pw - 2

    # pad so output tiles of m x m cover OH x OW exactly
    th, tw = -(-OH // m), -(-OW // m)
    Hp, Wp = m * th + 2, m * tw + 2
    xp = jnp.pad(x, ((0, 0), (ph, Hp - H - ph), (pw, Wp - W - pw), (0, 0)))

    # gather a x a input tiles with stride m (overlap 2)
    i_idx = (m * jnp.arange(th))[:, None] + jnp.arange(a)[None, :]  # (th,a)
    j_idx = (m * jnp.arange(tw))[:, None] + jnp.arange(a)[None, :]  # (tw,a)
    tiles = xp[:, i_idx][:, :, :, j_idx]            # (N, th, a, tw, a, C)
    tiles = tiles.transpose(0, 1, 3, 2, 4, 5)       # (N, th, tw, a, a, C)

    V = jnp.einsum("ij,nhwjkc,lk->nhwilc", BT, tiles.astype(jnp.float32), BT)
    U = transform_filters(w.astype(jnp.float32), m)  # (a, a, C, M)

    # elementwise product in the Winograd domain == a*a channel GEMMs
    Mdom = jnp.einsum("nhwijc,ijcm->nhwijm", V, U)  # (N, th, tw, a, a, M)

    Y = jnp.einsum("ij,nhwjkm,lk->nhwilm", AT, Mdom, AT)  # (..., m, m, M)
    out = Y.transpose(0, 1, 3, 2, 4, 5).reshape(N, m * th, m * tw, M)
    return out[:, :OH, :OW, :].astype(x.dtype)
