from repro.core.cuconv import (  # noqa: F401
    conv2d, cuconv_stage1, cuconv_stage2)
from repro.core.convspec import ConvSpec, ConvPlan, plan  # noqa: F401
from repro.core.executors import (  # noqa: F401
    ALGORITHMS, Executor, register, unregister)
from repro.core.graph import (  # noqa: F401
    AddOp, ConcatOp, ConvGraph, ConvOp, DenseOp, GapOp, Graph,
    GraphBuilder, GraphPlan, PoolOp, PrecisionPolicy, plan_graph)
