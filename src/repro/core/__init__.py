from repro.core.cuconv import (  # noqa: F401
    conv2d, cuconv_stage1, cuconv_stage2, ALGORITHMS)
