from repro.core.cuconv import (  # noqa: F401
    conv2d, cuconv_stage1, cuconv_stage2, ALGORITHMS)
from repro.core.convspec import ConvSpec, ConvPlan, plan  # noqa: F401
from repro.core.graph import (  # noqa: F401
    AddOp, ConcatOp, ConvGraph, ConvOp, DenseOp, GapOp, Graph,
    GraphBuilder, GraphPlan, PoolOp, plan_graph)
