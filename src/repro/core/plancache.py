"""Persisted JSON plan stores under ``$REPRO_CACHE_DIR``.

One small concern, shared by the measured-autotune cache
(``autotune.json``) and the graph-level plan cache (``graphplans.json``):
a string-keyed JSON map that survives across processes, merges with
concurrent writers instead of clobbering them, and degrades to
in-memory-only on a read-only filesystem.

The store itself is schema-agnostic; both clients persist
*schema-versioned* entries (``graph.GRAPH_SCHEMA`` dicts,
``autotune.AUTOTUNE_SCHEMA`` ``(algorithm, config)`` dicts) and drop
unversioned or foreign-schema values on read, so old caches are
re-resolved rather than misdecoded.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Dict, Optional


def cache_dir() -> Path:
    return Path(os.environ.get(
        "REPRO_CACHE_DIR",
        os.path.join(os.path.expanduser("~"), ".cache", "repro")))


class JsonCache:
    """A ``{str: json-value}`` map persisted to ``$REPRO_CACHE_DIR/<name>``."""

    def __init__(self, filename: str):
        self.filename = filename
        self._mem: Dict[str, Any] = {}
        self._loaded_from: Optional[Path] = None   # path _mem mirrors

    def path(self) -> Path:
        return cache_dir() / self.filename

    def _ensure_loaded(self) -> None:
        path = self.path()
        if path == self._loaded_from:
            return
        self._loaded_from = path
        self._mem = {}
        try:
            self._mem.update(json.loads(path.read_text()))
        except (OSError, ValueError):
            pass                        # no/corrupt cache: start empty

    def get(self, key: str, default: Any = None) -> Any:
        self._ensure_loaded()
        return self._mem.get(key, default)

    def put(self, key: str, value: Any) -> None:
        self._ensure_loaded()
        self._mem[key] = value
        self._persist()

    def clear(self) -> None:
        """Drop the in-memory mirror (tests); the JSON file is untouched."""
        self._loaded_from = None

    def _persist(self) -> None:
        path = self.path()
        try:
            path.parent.mkdir(parents=True, exist_ok=True)
            # merge what concurrent processes persisted since our load, so
            # a stale snapshot never clobbers their entries
            try:
                merged = json.loads(path.read_text())
            except (OSError, ValueError):
                merged = {}
            merged.update(self._mem)
            self._mem.update(merged)
            tmp = path.with_suffix(".tmp")
            tmp.write_text(json.dumps(merged, indent=0, sort_keys=True))
            os.replace(tmp, path)       # atomic: readers never see torn files
        except OSError:
            pass                        # read-only FS: stay in-memory only
