"""ConvSpec plan layer: one descriptor-driven entry point for all convs.

cuDNN's deployment story (and the paper's: "frameworks automatically
select the best-performing convolution algorithm for each layer") is a
descriptor + planner, not a pile of per-call-site heuristics.  This
module is that seam (DESIGN.md §4):

  ConvSpec   frozen descriptor of one convolution: shapes, stride,
             padding, dtype, epilogue.  Hashable; the key for every
             cache (measured autotune, serving plans).
  plan()     the ONLY place algorithm choice lives.  Consults, in order:
             a forced algorithm (with capability guards), the persisted
             measured-autotune cache, and the paper's heuristic regions;
             applies the fused-kernel VMEM budget fallback that used to
             hide in kernels/ops.py.
  ConvPlan   executable result: call it with (x, w, bias); `explain()`
             returns a stable one-line story for benchmarks/debugging.

Everything downstream (core.cuconv.conv2d, models.cnn, benchmarks,
serve) routes through plan(); kernels/ops.py stays policy-free.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

Pad = Union[int, Tuple[int, int], str]

# VMEM working-set budget for the fused Pallas kernel (per-core VMEM is
# ~16 MB; leave headroom for Mosaic's own buffers)
FUSED_VMEM_BUDGET = 12 * 1024 * 1024

EPILOGUES = ("none", "bias", "relu", "bias_relu")


def normalize_pad(padding: Pad, kh: int, kw: int) -> Tuple[int, int]:
    """Canonical (ph, pw) for any accepted padding form.

    The single home of padding normalization (cuconv and the kernels
    import it from here).  Rejects negative amounts and wrong-length
    tuples instead of silently truncating or wrapping them.
    """
    if padding == "same":
        return (kh - 1) // 2, (kw - 1) // 2
    if padding == "valid":
        return 0, 0
    if isinstance(padding, int):
        pad = (padding, padding)
    else:
        pad = tuple(padding)
    if len(pad) != 2:
        raise ValueError(f"padding must be 'same', 'valid', an int, or a "
                         f"(ph, pw) pair; got {padding!r}")
    ph, pw = pad
    if ph < 0 or pw < 0:
        raise ValueError(f"padding must be non-negative; got {padding!r}")
    return ph, pw


def normalize_stride(stride) -> Tuple[int, int]:
    """Canonical (sh, sw) stride pair (the single home; see normalize_pad)."""
    s = (stride, stride) if isinstance(stride, int) else tuple(stride)
    if len(s) != 2:
        raise ValueError(f"stride must be an int or an (sh, sw) pair; "
                         f"got {stride!r}")
    if s[0] < 1 or s[1] < 1:
        raise ValueError(f"stride must be >= 1; got {stride!r}")
    return s


def out_size(size: int, k: int, p: int, s: int) -> int:
    """Output extent of one spatial axis: (size + 2p - k) // s + 1."""
    return (size + 2 * p - k) // s + 1


# back-compat alias (pre-graph-API name)
_norm_stride = normalize_stride


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Descriptor of one convolution: the planner's (and caches') key."""
    in_shape: Tuple[int, int, int, int]       # (N, H, W, C) NHWC
    filter_shape: Tuple[int, int, int, int]   # (KH, KW, C/groups, M) HWIO
    stride: Tuple[int, int] = (1, 1)          # (sh, sw)
    padding: Tuple[int, int] = (0, 0)         # (ph, pw), pre-normalized
    dtype: str = "float32"
    epilogue: str = "none"                    # none | bias | relu | bias_relu
    groups: int = 1                           # feature groups (depthwise: C)

    def __post_init__(self):
        if self.epilogue not in EPILOGUES:
            raise ValueError(f"epilogue {self.epilogue!r} not in {EPILOGUES}")
        if not isinstance(self.groups, int) or self.groups < 1:
            raise ValueError(f"groups must be a positive int; "
                             f"got {self.groups!r}")
        if self.in_shape[3] != self.filter_shape[2] * self.groups:
            raise ValueError(
                f"channel mismatch: input {self.in_shape} needs filter "
                f"depth {self.in_shape[3]} / groups={self.groups}; "
                f"filter {self.filter_shape}")
        if self.filter_shape[3] % self.groups:
            raise ValueError(f"output channels {self.filter_shape[3]} not "
                             f"divisible by groups={self.groups}")
        # direct construction must be as strict as the normalize_* path
        if len(self.stride) != 2 or any(s < 1 for s in self.stride):
            raise ValueError(f"stride must be an (sh, sw) pair >= 1; "
                             f"got {self.stride!r}")
        if len(self.padding) != 2 or any(p < 0 for p in self.padding):
            raise ValueError(f"padding must be a non-negative (ph, pw) "
                             f"pair; got {self.padding!r}")
        if any(d <= 0 for d in self.out_shape):
            raise ValueError(f"spec produces non-positive output shape "
                             f"{self.out_shape}: input {self.in_shape}, "
                             f"filter {self.filter_shape}, stride "
                             f"{self.stride}, padding {self.padding}")

    @classmethod
    def for_conv(cls, x, w, stride=1, padding: Pad = "same",
                 bias=None, activation: Optional[str] = None,
                 groups: int = 1) -> "ConvSpec":
        """Build a spec from (possibly traced) operands + call options.

        Unknown activations are an error, not a silent epilogue "none":
        the planner only knows how to fuse what EPILOGUES names.
        """
        if activation not in (None, "none", "relu"):
            raise ValueError(
                f"activation {activation!r} not supported; the planner "
                f"fuses None or 'relu' (epilogues: {EPILOGUES})")
        relu = activation == "relu"
        kh, kw = int(w.shape[0]), int(w.shape[1])
        epi = ("bias_relu" if bias is not None and relu
               else "bias" if bias is not None
               else "relu" if relu else "none")
        return cls(tuple(map(int, x.shape)), tuple(map(int, w.shape)),
                   normalize_stride(stride), normalize_pad(padding, kh, kw),
                   str(x.dtype), epi, int(groups))

    # -- derived geometry ------------------------------------------------
    @property
    def out_shape(self) -> Tuple[int, int, int, int]:
        n, h, w, _ = self.in_shape
        kh, kw, _, m = self.filter_shape
        (sh, sw), (ph, pw) = self.stride, self.padding
        return (n, out_size(h, kh, ph, sh), out_size(w, kw, pw, sw), m)

    @property
    def is_1x1(self) -> bool:
        return self.filter_shape[0] == 1 and self.filter_shape[1] == 1

    @property
    def unit_stride(self) -> bool:
        return self.stride == (1, 1)

    @property
    def has_bias(self) -> bool:
        return self.epilogue in ("bias", "bias_relu")

    @property
    def wants_relu(self) -> bool:
        return self.epilogue in ("relu", "bias_relu")

    def key(self) -> str:
        """Stable string key for persisted caches.

        Ungrouped specs keep the historical key shape (no ``-g`` segment)
        so pre-groups persisted autotune entries stay valid.
        """
        n, h, w, c = self.in_shape
        kh, kw, _, m = self.filter_shape
        g = f"-g{self.groups}" if self.groups != 1 else ""
        return (f"n{n}h{h}w{w}c{c}-k{kh}x{kw}m{m}-s{self.stride[0]}x"
                f"{self.stride[1]}-p{self.padding[0]}x{self.padding[1]}-"
                f"{self.dtype}-{self.epilogue}{g}")


# ---------------------------------------------------------------------------
# capability / cost model

def fused_vmem_bytes(spec: ConvSpec) -> int:
    from repro.kernels.cuconv_fused import vmem_bytes
    itemsize = jnp.dtype(spec.dtype).itemsize
    return vmem_bytes(spec.in_shape, spec.filter_shape, pad=spec.padding,
                      stride=spec.stride, itemsize=itemsize)


def supports(algorithm: str, spec: ConvSpec) -> Tuple[bool, str]:
    """Can `algorithm` execute `spec` exactly (ignoring speed)?"""
    if spec.groups != 1:
        # no dedicated grouped/depthwise kernel yet: only the library
        # conv (feature_group_count) executes grouped specs exactly
        if algorithm == "lax":
            return True, (f"grouped conv (groups={spec.groups}): library "
                          f"feature_group_count")
        return False, (f"no grouped-conv support (groups={spec.groups}); "
                       f"lax feature_group_count is the executor")
    if algorithm == "cuconv_pallas":
        if fused_vmem_bytes(spec) > FUSED_VMEM_BUDGET:
            return False, (f"fused working set "
                           f"{fused_vmem_bytes(spec) / 2**20:.1f} MB "
                           f"> {FUSED_VMEM_BUDGET / 2**20:.0f} MB VMEM budget")
        return True, "fused Pallas kernel fits VMEM"
    if algorithm == "conv1x1_pallas":
        if (not spec.is_1x1 or not spec.unit_stride
                or spec.padding != (0, 0)):
            return False, "conv1x1 kernel needs 1x1 filter, stride 1, pad 0"
        return True, "1x1 GEMM kernel (all pixels MXU-tiled)"
    if algorithm == "cuconv_two_stage_pallas" and not spec.unit_stride:
        return False, "two-stage Pallas kernels are stride-1 only"
    if algorithm == "winograd":
        # executor falls back to lax internally for non-3x3; treat the
        # non-Winograd region as unsupported so plans stay honest
        if spec.filter_shape[:2] != (3, 3) or not spec.unit_stride:
            return False, "Winograd F(2x2,3x3) needs 3x3 stride-1"
        return True, "3x3 stride-1: Winograd region"
    return True, "generic algorithm"


def heuristic_algorithm(spec: ConvSpec, backend: str) -> Tuple[str, str]:
    """The paper's empirical regions (figs 5-7), adapted per backend.

    - 1x1 filters: cuConv's best region (single GEMM, no stage 2);
    - small batch + small spatial: cuConv wins (its thread-level
      parallelism advantage on GPU; on TPU the grid fills cores even at
      batch 1);
    - large 3x3 workloads: the library algorithm (Winograd's region in
      the paper) keeps the edge;
    - on TPU the fused Pallas kernel takes any region cuConv would,
      including strided convs; elsewhere Pallas runs in interpret mode
      (orders of magnitude slower), so XLA paths are chosen instead.
    """
    n, h, _, _ = spec.in_shape
    kh, kw = spec.filter_shape[:2]
    on_tpu = backend == "tpu"
    if spec.groups != 1:
        return "lax", (f"grouped conv (groups={spec.groups}): library "
                       f"feature_group_count")
    fused_ok, _ = supports("cuconv_pallas", spec)
    if not spec.unit_stride:
        if on_tpu and fused_ok:
            return "cuconv_pallas", "strided conv: fused kernel on TPU"
        return "lax", "strided conv: library kernel off-TPU"
    if spec.is_1x1:
        if on_tpu and spec.epilogue == "none" and supports(
                "conv1x1_pallas", spec)[0]:
            # no epilogue to fuse: the dedicated GEMM kernel tiles all
            # N*H*W pixels onto the MXU (the fused kernel only fills
            # OW rows per grid step)
            return "conv1x1_pallas", "1x1: dedicated GEMM kernel"
        if on_tpu and fused_ok:
            return "cuconv_pallas", "1x1: fused GEMM + epilogue in VMEM"
        return "cuconv", "1x1: single GEMM, no stage 2 (best region)"
    if n == 1 or (h <= 14 and n <= 16):
        if on_tpu and fused_ok:
            return "cuconv_pallas", "small batch/spatial: cuConv region"
        return "cuconv", "small batch/spatial: cuConv region"
    if kh == 3 and kw == 3:
        return "winograd", "large 3x3: Winograd region in the paper"
    return "cuconv", "default cuConv region"


# ---------------------------------------------------------------------------
# plan

# Observable resolution count: every plan() call increments it, and
# NOTHING else does.  The graph layer's plan-once contract is asserted
# against this ("warmup then N inferences adds zero resolutions").
PLAN_STATS = {"resolutions": 0}


def reset_plan_stats() -> int:
    """Zero the resolution counter (tests use this, not dict-poking);
    returns the count that was discarded."""
    old = PLAN_STATS["resolutions"]
    PLAN_STATS["resolutions"] = 0
    return old


@dataclasses.dataclass(frozen=True)
class ConvPlan:
    """Executable algorithm choice for one ConvSpec."""
    spec: ConvSpec
    algorithm: str
    source: str                       # heuristic | measured | forced | fallback
    reason: str
    backend: str = "cpu"
    interpret: Optional[bool] = None  # forwarded to Pallas executors

    def explain(self) -> str:
        return (f"{self.spec.key()} -> {self.algorithm} "
                f"[{self.source}] {self.reason}")

    # -- execution -------------------------------------------------------
    def __call__(self, x, w, bias=None):
        spec = self.spec
        if spec.has_bias and bias is None:
            raise ValueError(f"plan epilogue {spec.epilogue!r} needs a bias")
        if self.algorithm == "cuconv_pallas":
            # epilogue fused into the kernel: accumulator takes
            # bias+activation in VMEM before its single HBM write
            from repro.kernels import ops
            return ops.cuconv_fused(
                x, w, spec.padding, stride=spec.stride,
                bias=bias if spec.has_bias else None,
                activation="relu" if spec.wants_relu else None,
                interpret=self.interpret)
        from repro.core import cuconv
        kwargs = {}
        if self.algorithm in ("conv1x1_pallas", "cuconv_two_stage_pallas"):
            kwargs["interpret"] = self.interpret   # honor debug requests
        if spec.groups != 1:
            # supports() routes every grouped spec to the library conv
            kwargs["groups"] = spec.groups
        y = cuconv.ALGORITHMS[self.algorithm](
            x, w, stride=spec.stride, padding=spec.padding, **kwargs)
        # two-stage epilogue for non-fused paths: one extra HBM round trip
        if spec.has_bias:
            y = y + bias
        if spec.wants_relu:
            y = jax.nn.relu(y)
        return y


def plan(spec: ConvSpec, force: Optional[str] = None,
         backend: Optional[str] = None,
         interpret: Optional[bool] = None) -> ConvPlan:
    """All conv algorithm choice, in one place.

    Order: forced algorithm (capability-guarded, falling back like the
    old ops.py VMEM check did) > persisted measured-autotune winner >
    paper-region heuristic.
    """
    PLAN_STATS["resolutions"] += 1
    backend = backend or jax.default_backend()

    if force is not None:
        from repro.core import cuconv
        if force not in cuconv.ALGORITHMS:
            raise KeyError(f"unknown algorithm {force!r}; "
                           f"known: {sorted(cuconv.ALGORITHMS)}")
        ok, why = supports(force, spec)
        if ok:
            return ConvPlan(spec, force, "forced", why, backend, interpret)
        fb, fb_why = _fallback_for(force, spec)
        return ConvPlan(spec, fb, "fallback",
                        f"{force} unsupported ({why}); {fb_why}",
                        backend, interpret)

    from repro.core import autotune
    measured = autotune.cached_best(spec, backend)
    if measured is not None and supports(measured, spec)[0]:
        return ConvPlan(spec, measured, "measured",
                        "persisted autotune winner", backend, interpret)

    algo, reason = heuristic_algorithm(spec, backend)
    return ConvPlan(spec, algo, "heuristic", reason, backend, interpret)


def _fallback_for(algorithm: str, spec: ConvSpec) -> Tuple[str, str]:
    """Closest supported stand-in for an unsupported forced algorithm."""
    if spec.groups != 1:
        return "lax", "feature_group_count executes grouped convs"
    if algorithm == "cuconv_pallas":
        if spec.unit_stride:
            # the old kernels/ops.py behaviour: oversized rows take the
            # two-stage Pallas kernels (HBM temporaries, bounded VMEM)
            return ("cuconv_two_stage_pallas",
                    "two-stage kernels bound the VMEM working set")
        return "cuconv", "fused-tap XLA path handles any stride"
    return "lax", "library conv covers all geometries"
