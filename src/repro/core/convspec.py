"""ConvSpec plan layer: one descriptor-driven entry point for all convs.

cuDNN's deployment story (and the paper's: "frameworks automatically
select the best-performing convolution algorithm for each layer") is a
descriptor + planner, not a pile of per-call-site heuristics.  This
module is that seam (DESIGN.md §4):

  ConvSpec   frozen descriptor of one convolution: shapes, stride,
             padding, dtype, epilogue, groups.  Hashable; the key for
             every cache (measured autotune, serving plans).
  plan()     the ONLY place algorithm choice lives — and it is pure
             capability negotiation over the executor registry
             (core/executors.py): a forced executor (capability-
             guarded), the persisted measured-autotune cache, the
             registered executors' heuristic region claims, then the
             cheapest supported executor by cost model.  No executor
             name is special-cased here.
  ConvPlan   executable result: call it with (x, w, bias); `explain()`
             returns a stable one-line story (executor provenance +
             dtype/accumulation) for benchmarks/debugging.

Everything downstream (core.cuconv.conv2d, models.cnn, benchmarks,
serve) routes through plan(); kernels/ops.py stays policy-free, and
capability rules live on the executors themselves (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

# the single home of the padding type alias (cuconv re-exports it)
Pad = Union[int, Tuple[int, int], str]

EPILOGUES = ("none", "bias", "relu", "bias_relu")

# canonical short spellings for ConvSpec.dtype / PrecisionPolicy inputs
_DTYPE_ALIASES = {"fp32": "float32", "f32": "float32",
                  "bf16": "bfloat16", "bfloat16": "bfloat16",
                  "float32": "float32", "i8": "int8", "int8": "int8"}


def canonical_dtype(dtype) -> str:
    """One canonical dtype string ('float32', 'bfloat16', ...) for any
    accepted spelling ('bf16', jnp.bfloat16, np.dtype('float32'), ...)."""
    alias = _DTYPE_ALIASES.get(str(dtype))
    if alias is not None:
        return alias
    try:
        return str(jnp.dtype(dtype).name)
    except TypeError as e:
        raise ValueError(f"unknown dtype {dtype!r}") from e


def normalize_pad(padding: Pad, kh: int, kw: int) -> Tuple[int, int]:
    """Canonical (ph, pw) for any accepted padding form.

    The single home of padding normalization (cuconv and the kernels
    import it from here).  Rejects negative amounts and wrong-length
    tuples instead of silently truncating or wrapping them.
    """
    if padding == "same":
        return (kh - 1) // 2, (kw - 1) // 2
    if padding == "valid":
        return 0, 0
    if isinstance(padding, int):
        pad = (padding, padding)
    else:
        pad = tuple(padding)
    if len(pad) != 2:
        raise ValueError(f"padding must be 'same', 'valid', an int, or a "
                         f"(ph, pw) pair; got {padding!r}")
    ph, pw = pad
    if ph < 0 or pw < 0:
        raise ValueError(f"padding must be non-negative; got {padding!r}")
    return ph, pw


def normalize_stride(stride) -> Tuple[int, int]:
    """Canonical (sh, sw) stride pair (the single home; see normalize_pad)."""
    s = (stride, stride) if isinstance(stride, int) else tuple(stride)
    if len(s) != 2:
        raise ValueError(f"stride must be an int or an (sh, sw) pair; "
                         f"got {stride!r}")
    if s[0] < 1 or s[1] < 1:
        raise ValueError(f"stride must be >= 1; got {stride!r}")
    return s


def out_size(size: int, k: int, p: int, s: int) -> int:
    """Output extent of one spatial axis: (size + 2p - k) // s + 1."""
    return (size + 2 * p - k) // s + 1


# back-compat alias (pre-graph-API name; the ONE declared home — other
# modules import it rather than re-declaring)
_norm_stride = normalize_stride


# cross-layer fusions a ConvSpec can carry in its epilogue (DESIGN.md §10)
FUSED_ADDS = ("none", "add", "add_relu")


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Descriptor of one convolution: the planner's (and caches') key.

    ``fused_add``/``fused_pool`` describe *cross-layer* epilogue fusions
    the graph-level fusion pass (core/graph.py, DESIGN.md §10) folds
    into a conv node: a residual-add second operand (with optional
    post-add ReLU), or a trailing max/avg pool consuming the conv
    output before it ever reaches HBM.  Both ride ``key()`` so every
    cache — measured autotune, graph signatures — is fusion-distinct,
    and both are *capability-negotiated*: executors refuse fused specs
    whose fusions they do not declare (``Executor.fusions``).
    """
    in_shape: Tuple[int, int, int, int]       # (N, H, W, C) NHWC
    filter_shape: Tuple[int, int, int, int]   # (KH, KW, C/groups, M) HWIO
    stride: Tuple[int, int] = (1, 1)          # (sh, sw)
    padding: Tuple[int, int] = (0, 0)         # (ph, pw), pre-normalized
    dtype: str = "float32"
    epilogue: str = "none"                    # none | bias | relu | bias_relu
    groups: int = 1                           # feature groups (depthwise: C)
    #: residual-add fusion: a second operand (shape == out_shape) added
    #: after the bias, with 'add_relu' applying ReLU after the sum
    fused_add: str = "none"                   # none | add | add_relu
    #: pool fusion: (kind, kh, kw, sh, sw, ph, pw) applied to the conv
    #: output (post-epilogue), or () for no pool
    fused_pool: Tuple = ()

    def __post_init__(self):
        if self.epilogue not in EPILOGUES:
            raise ValueError(f"epilogue {self.epilogue!r} not in {EPILOGUES}")
        if self.fused_add not in FUSED_ADDS:
            raise ValueError(f"fused_add {self.fused_add!r} not in "
                             f"{FUSED_ADDS}")
        if self.fused_add != "none":
            if self.wants_relu:
                raise ValueError(
                    f"fused_add {self.fused_add!r} needs epilogue 'none' or "
                    f"'bias' (the activation moves AFTER the add); got "
                    f"epilogue {self.epilogue!r}")
            if self.fused_pool:
                raise ValueError("a spec carries at most one cross-layer "
                                 "fusion: fused_add and fused_pool are "
                                 "mutually exclusive")
        if self.fused_pool:
            fp = tuple(self.fused_pool)
            if len(fp) != 7 or fp[0] not in ("max", "avg"):
                raise ValueError(
                    f"fused_pool must be (kind, kh, kw, sh, sw, ph, pw) "
                    f"with kind 'max'|'avg'; got {self.fused_pool!r}")
            kind, pkh, pkw, psh, psw, pph, ppw = fp
            if min(pkh, pkw, psh, psw) < 1 or min(pph, ppw) < 0:
                raise ValueError(f"fused_pool geometry must be positive "
                                 f"windows/strides and non-negative "
                                 f"padding; got {self.fused_pool!r}")
            object.__setattr__(self, "fused_pool",
                               (str(kind),) + tuple(map(int, fp[1:])))
        if not isinstance(self.groups, int) or self.groups < 1:
            raise ValueError(f"groups must be a positive int; "
                             f"got {self.groups!r}")
        if self.in_shape[3] != self.filter_shape[2] * self.groups:
            raise ValueError(
                f"channel mismatch: input {self.in_shape} needs filter "
                f"depth {self.in_shape[3]} / groups={self.groups}; "
                f"filter {self.filter_shape}")
        if self.filter_shape[3] % self.groups:
            raise ValueError(f"output channels {self.filter_shape[3]} not "
                             f"divisible by groups={self.groups}")
        # direct construction must be as strict as the normalize_* path
        if len(self.stride) != 2 or any(s < 1 for s in self.stride):
            raise ValueError(f"stride must be an (sh, sw) pair >= 1; "
                             f"got {self.stride!r}")
        if len(self.padding) != 2 or any(p < 0 for p in self.padding):
            raise ValueError(f"padding must be a non-negative (ph, pw) "
                             f"pair; got {self.padding!r}")
        # canonicalize dtype so 'bf16' and 'bfloat16' share cache keys
        object.__setattr__(self, "dtype", canonical_dtype(self.dtype))
        if any(d <= 0 for d in self.out_shape):
            raise ValueError(f"spec produces non-positive output shape "
                             f"{self.out_shape}: input {self.in_shape}, "
                             f"filter {self.filter_shape}, stride "
                             f"{self.stride}, padding {self.padding}")

    @classmethod
    def for_conv(cls, x, w, stride=1, padding: Pad = "same",
                 bias=None, activation: Optional[str] = None,
                 groups: int = 1) -> "ConvSpec":
        """Build a spec from (possibly traced) operands + call options.

        Unknown activations are an error, not a silent epilogue "none":
        the planner only knows how to fuse what EPILOGUES names.
        """
        if activation not in (None, "none", "relu"):
            raise ValueError(
                f"activation {activation!r} not supported; the planner "
                f"fuses None or 'relu' (epilogues: {EPILOGUES})")
        relu = activation == "relu"
        kh, kw = int(w.shape[0]), int(w.shape[1])
        epi = ("bias_relu" if bias is not None and relu
               else "bias" if bias is not None
               else "relu" if relu else "none")
        return cls(tuple(map(int, x.shape)), tuple(map(int, w.shape)),
                   normalize_stride(stride), normalize_pad(padding, kh, kw),
                   str(x.dtype), epi, int(groups))

    # -- derived geometry ------------------------------------------------
    @property
    def out_shape(self) -> Tuple[int, int, int, int]:
        n, h, w, _ = self.in_shape
        kh, kw, _, m = self.filter_shape
        (sh, sw), (ph, pw) = self.stride, self.padding
        return (n, out_size(h, kh, ph, sh), out_size(w, kw, pw, sw), m)

    @property
    def is_1x1(self) -> bool:
        return self.filter_shape[0] == 1 and self.filter_shape[1] == 1

    @property
    def unit_stride(self) -> bool:
        return self.stride == (1, 1)

    @property
    def has_bias(self) -> bool:
        return self.epilogue in ("bias", "bias_relu")

    @property
    def wants_relu(self) -> bool:
        return self.epilogue in ("relu", "bias_relu")

    @property
    def has_fusion(self) -> bool:
        """Does this spec carry a cross-layer fusion (add or pool)?"""
        return self.fused_add != "none" or bool(self.fused_pool)

    @property
    def final_shape(self) -> Tuple[int, int, int, int]:
        """Shape this spec's execution ultimately yields: ``out_shape``
        for plain/fused-add specs, the pooled shape for fused-pool."""
        if not self.fused_pool:
            return self.out_shape
        _, pkh, pkw, psh, psw, pph, ppw = self.fused_pool
        n, oh, ow, m = self.out_shape
        return (n, out_size(oh, pkh, pph, psh),
                out_size(ow, pkw, ppw, psw), m)

    def unfused(self) -> "ConvSpec":
        """This spec with cross-layer fusions stripped (the plain conv
        the fusion pass started from; epilogue/bias are preserved)."""
        if not self.has_fusion:
            return self
        return dataclasses.replace(self, fused_add="none", fused_pool=())

    def key(self) -> str:
        """Stable string key for persisted caches.

        The dtype segment makes keys precision-distinct (a bf16 plan can
        never serve an fp32 spec); ungrouped specs keep the historical
        key shape (no ``-g`` segment) so pre-groups persisted autotune
        entries stay valid.
        """
        n, h, w, c = self.in_shape
        kh, kw, _, m = self.filter_shape
        g = f"-g{self.groups}" if self.groups != 1 else ""
        fused = ""
        if self.fused_add != "none":
            fused = "-fadd" if self.fused_add == "add" else "-faddrelu"
        elif self.fused_pool:
            kind, pkh, pkw, psh, psw, pph, ppw = self.fused_pool
            fused = (f"-fpool{kind}{pkh}x{pkw}s{psh}x{psw}p{pph}x{ppw}")
        return (f"n{n}h{h}w{w}c{c}-k{kh}x{kw}m{m}-s{self.stride[0]}x"
                f"{self.stride[1]}-p{self.padding[0]}x{self.padding[1]}-"
                f"{self.dtype}-{self.epilogue}{g}{fused}")


# ---------------------------------------------------------------------------
# capability: a thin delegation to the executor registry (the rules
# themselves live on the registered executors — DESIGN.md §8)

def supports(algorithm: str, spec: ConvSpec) -> Tuple[bool, str]:
    """Can `algorithm` execute `spec` exactly (ignoring speed)?

    Back-compat wrapper over ``executors.get(algorithm).supports(spec)``.
    """
    from repro.core import executors
    return executors.get(algorithm).supports(spec)


def heuristic_algorithm(spec: ConvSpec, backend: str) -> Tuple[str, str]:
    """The negotiated choice absent force/measurement: the executors'
    paper-region claims (figs 5-7), else the cheapest supported
    executor by cost model.  Back-compat wrapper over
    ``executors.negotiate``."""
    from repro.core import executors
    name, _source, reason = executors.negotiate(spec, backend)
    return name, reason


# ---------------------------------------------------------------------------
# plan

# Observable resolution count: every plan() call increments it, and
# NOTHING else does.  The graph layer's plan-once contract is asserted
# against this ("warmup then N inferences adds zero resolutions").
PLAN_STATS = {"resolutions": 0}


def reset_plan_stats() -> int:
    """Zero the resolution counter (tests use this, not dict-poking);
    returns the count that was discarded."""
    old = PLAN_STATS["resolutions"]
    PLAN_STATS["resolutions"] = 0
    return old


@dataclasses.dataclass(frozen=True)
class ConvPlan:
    """Executable ``(algorithm, launch config)`` choice for one ConvSpec."""
    spec: ConvSpec
    algorithm: str
    source: str          # heuristic | cost | measured | forced | fallback
    reason: str
    backend: str = "cpu"
    interpret: Optional[bool] = None  # forwarded to Pallas executors
    #: resolved launch config (executors.LaunchConfig; empty/None for
    #: untunable executors) and its provenance
    config: Optional[object] = None
    config_source: str = "default"    # default | measured | forced
    #: quantization payload (quant.policy.QuantInfo) for int8 specs: the
    #: calibrated per-tensor activation scale + its provenance.  None on
    #: fp plans AND on int8 plans resolved outside the quantize pass
    #: (autotune timing) — the executor then falls back to a dynamic
    #: in-trace scale
    quant: Optional[object] = None

    @property
    def executor(self):
        """The registry entry this plan resolves to."""
        from repro.core import executors
        return executors.get(self.algorithm)

    def explain(self) -> str:
        ex = self.executor
        cfg = (f" cfg[{self.config_source}]={self.config.key()}"
               if self.config else "")
        q = f" quant[{self.quant.key()}]" if self.quant else ""
        return (f"{self.spec.key()} -> {self.algorithm} "
                f"[{self.source}]{cfg}{q} dtype={self.spec.dtype} "
                f"accum={ex.accum} {self.reason}")

    # -- execution -------------------------------------------------------
    def __call__(self, x, w, bias=None, addend=None):
        spec = self.spec
        if spec.has_bias and bias is None:
            raise ValueError(f"plan epilogue {spec.epilogue!r} needs a bias")
        if spec.fused_add != "none" and addend is None:
            raise ValueError(f"plan for fused-add spec {spec.key()} needs "
                             f"an addend (the residual operand)")
        if spec.fused_add == "none" and addend is not None:
            raise ValueError(f"plan for spec {spec.key()} does not take an "
                             f"addend (fused_add='none')")
        kwargs = {}
        if self.quant is not None:
            # only int8-aware executors ever receive the payload — the
            # quantize pass attaches it exclusively to plans whose
            # executor declared int8 support
            kwargs["quant"] = self.quant
        return self.executor.execute(
            spec, x, w, bias=bias if spec.has_bias else None,
            addend=addend, interpret=self.interpret, config=self.config,
            **kwargs)


def resolve_config(spec: ConvSpec, algorithm: str,
                   backend: str) -> Tuple[object, str]:
    """``(launch config, provenance)`` for an already-chosen algorithm.

    The persisted measured winner serves if it is still valid for this
    spec under the executor's current declarations (a stale config —
    e.g. ``rows`` larger than OH after a geometry change, or a
    tightened VMEM budget — is dropped, never served); otherwise the
    executor's model-chosen ``default_config``.
    """
    from repro.core import autotune, executors
    ex = executors.get(algorithm)
    cached = autotune.cached_config(spec, backend, algorithm)
    if cached is not None and ex.config_supports(spec, cached)[0]:
        return cached, "measured"
    return ex.default_config(spec), "default"


def _with_config(spec, algorithm, source, reason, backend, interpret,
                 config) -> ConvPlan:
    """Attach the resolved (or caller-forced) launch config to a plan."""
    from repro.core import executors
    if config is not None:
        cfg = executors.LaunchConfig.of(config)
        ok, why = executors.get(algorithm).config_supports(spec, cfg)
        if not ok:
            raise ValueError(
                f"forced launch config {cfg.as_dict()} is not supported "
                f"by executor {algorithm!r} for spec {spec.key()}: {why}")
        return ConvPlan(spec, algorithm, source, reason, backend, interpret,
                        cfg, "forced")
    cfg, cfg_src = resolve_config(spec, algorithm, backend)
    return ConvPlan(spec, algorithm, source, reason, backend, interpret,
                    cfg, cfg_src)


def plan(spec: ConvSpec, force: Optional[str] = None,
         backend: Optional[str] = None,
         interpret: Optional[bool] = None,
         tune: Optional[str] = None,
         config=None) -> ConvPlan:
    """All conv algorithm choice, in one place — capability negotiation
    over the executor registry — resolving an ``(algorithm, launch
    config)`` pair.

    Algorithm order: forced executor (capability-guarded; an unsupported
    forced choice takes the executor's declared fallback, except grouped
    specs, which raise rather than silently running a different
    algorithm than the caller demanded) > persisted measured-autotune
    winner > the executors' heuristic region claims > cheapest supported
    executor.

    ``tune`` runs the measured sweep first (``"algo"``: time every
    capable executor — with ``force`` the sweep still runs and records
    the unforced winner, the pin only decides what THIS plan serves;
    ``"full"``: sweep the candidate launch configs of the forced
    executor, or of the winner after an algorithm sweep) and persists
    the winners, so the very plan returned already serves them — and
    every later ``plan()`` replays them from cache with zero
    re-measurement.  ``config`` forces a launch config
    (validated against the executor's ``config_supports`` — an
    infeasible forced config raises naming executor, config and spec);
    otherwise the persisted measured config (if still valid) or the
    executor's model-chosen ``default_config`` rides the plan.
    """
    PLAN_STATS["resolutions"] += 1
    backend = backend or jax.default_backend()
    from repro.core import executors

    if tune not in (None, "algo", "full"):
        raise ValueError(f'tune must be None, "algo" or "full"; '
                         f'got {tune!r}')
    if tune is not None:
        from repro.core import autotune
        autotune.tune_spec(spec, tune=tune, backend=backend,
                           algorithm=force)

    if force is not None:
        ex = executors.get(force)      # KeyError names the registry
        ok, why = ex.supports(spec)
        if ok:
            return _with_config(spec, force, "forced", why, backend,
                                interpret, config)
        if spec.groups != 1 and not ex.supports_groups:
            # a grouped spec has no numerically-equivalent stand-in among
            # ungrouped executors: falling back would silently ignore the
            # caller's explicit choice, so refuse loudly instead
            raise ValueError(
                f"forced algorithm {force!r} cannot execute grouped spec "
                f"{spec.key()} (groups={spec.groups}): {why}.  Force an "
                f"executor that declares grouped support (e.g. 'lax') or "
                f"let plan() negotiate.")
        fb, fb_why = ex.fallback(spec)
        fb_ok, fb_refusal = executors.get(fb).supports(spec)
        if not fb_ok:
            raise ValueError(
                f"forced algorithm {force!r} cannot execute {spec.key()} "
                f"({why}), and its declared fallback {fb!r} cannot either "
                f"({fb_refusal})")
        return _with_config(spec, fb, "fallback",
                            f"{force} unsupported ({why}); {fb_why}",
                            backend, interpret, config)

    from repro.core import autotune
    measured = autotune.cached_best(spec, backend)
    if measured is not None and executors.capable(measured, spec):
        return _with_config(spec, measured, "measured",
                            "persisted autotune winner", backend,
                            interpret, config)

    algo, source, reason = executors.negotiate(spec, backend)
    return _with_config(spec, algo, source, reason, backend, interpret,
                        config)
