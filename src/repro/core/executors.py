"""Executor registry: the open menu of convolution algorithms.

cuDNN's deployment story — the one the paper leans on ("frameworks
automatically select the best-performing convolution algorithm for each
layer") — is an *algorithm enum plus capability query*: a menu of
implementations, each answering "can you run this descriptor?" before
anyone asks "how fast?".  This module is that seam as a first-class,
third-party-extensible API (DESIGN.md §8).  Every algorithm is a
registered ``Executor`` object declaring:

  name             stable string identity — what ``ConvPlan.algorithm``,
                   ``conv2d(algorithm=...)`` and the persisted
                   autotune/graphplans cache entries resolve through
  dtypes / accum   supported input dtypes and accumulation behavior
                   (every built-in accumulates fp32 for bf16 inputs via
                   ``preferred_element_type`` or an f32 VMEM accumulator)
  supports(spec)   exact capability over stride / groups / kernel size /
                   dtype / VMEM working set
  heuristic_claim  the executor's claim on the paper's empirical regions
                   (figs 5-7), scored so negotiation can rank rivals
  cost(spec)       abstract cost model (MACs + weighted extra HBM
                   traffic) for the cheapest-supported tier
  vmem_bytes(spec, config)
                   optional VMEM working-set model (also the pre-
                   measurement pruner for candidate launch configs)
  configs(spec)    ordered candidate *launch configs* (tile sizes,
                   rows-per-step; DESIGN.md §9) — candidate 0 is the
                   historical hard-coded geometry; ``config_supports``
                   prunes, ``default_config`` model-picks absent
                   measurement, ``autotune.measure_config`` sweeps
  execute(...)     run the spec under a launch config, epilogue
                   included (in-kernel when ``fuses_epilogue``, XLA
                   ops otherwise)

``convspec.plan()`` is pure negotiation over these declarations
(forced > measured cache > heuristic claims > cheapest supported);
nothing outside this module special-cases an executor name.  Adding a
kernel — in-tree or third-party — is one ``register(MyExecutor())``
call, not a planner edit (README "Registering a third-party executor").
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
from collections.abc import Mapping as _MappingABC
from typing import Callable, Dict, Iterable, Optional, Tuple

import jax.numpy as jnp

# VMEM working-set budget for the fused Pallas kernel (per-core VMEM is
# ~16 MB; leave headroom for Mosaic's own buffers).  Read at supports()
# time so tests and deployments can adjust it.
FUSED_VMEM_BUDGET = 12 * 1024 * 1024

# cost-model exchange rate: abstract cost units per byte of extra HBM
# traffic (a memory-bound conv does O(10) MACs per byte at the balance
# point; the exact number only has to rank executors, not predict time)
_COST_PER_HBM_BYTE = 8.0


def _is_small(spec) -> bool:
    """The paper's small-batch/small-spatial region (figs 5-7)."""
    n, h = spec.in_shape[0], spec.in_shape[1]
    return n == 1 or (h <= 14 and n <= 16)


# ---------------------------------------------------------------------------
# launch configurations (DESIGN.md §9)

@dataclasses.dataclass(frozen=True)
class LaunchConfig:
    """One launch configuration: named integer kernel-geometry dims.

    Immutable and hashable (it rides inside frozen ``ConvPlan``s) and
    JSON-round-trippable via ``as_dict`` (the persisted autotune cache).
    An *empty* config (the untunable executors' only candidate) is
    falsy, so callers can write ``if plan.config: ...``.
    """
    dims: Tuple[Tuple[str, int], ...] = ()

    @classmethod
    def of(cls, value) -> "LaunchConfig":
        """Coerce any accepted spelling (LaunchConfig | mapping of
        str -> int | None) into a LaunchConfig."""
        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        if isinstance(value, _MappingABC):
            try:
                dims = tuple(sorted((str(k), int(v))
                                    for k, v in value.items()))
            except (TypeError, ValueError) as e:
                raise ValueError(f"launch-config dims must be str -> int; "
                                 f"got {dict(value)!r}") from e
            return cls(dims)
        raise ValueError(f"cannot build a LaunchConfig from {value!r}")

    def as_dict(self) -> Dict[str, int]:
        return dict(self.dims)

    def get(self, name: str, default: Optional[int] = None) -> Optional[int]:
        for k, v in self.dims:
            if k == name:
                return v
        return default

    def __getitem__(self, name: str) -> int:
        v = self.get(name)
        if v is None:
            raise KeyError(name)
        return v

    def __contains__(self, name: str) -> bool:
        return self.get(name) is not None

    def __bool__(self) -> bool:
        return bool(self.dims)

    def key(self) -> str:
        """Stable one-token rendering for explain()/benchmark rows."""
        return ",".join(f"{k}={v}" for k, v in self.dims) or "-"


def _dedup_configs(dicts: Iterable[Dict[str, int]]
                   ) -> Tuple[LaunchConfig, ...]:
    """Ordered, deduplicated candidate list (clamped candidates often
    collapse on small paper shapes — e.g. every tp > N*OH*OW)."""
    out, seen = [], set()
    for d in dicts:
        c = LaunchConfig.of(d)
        if c.dims not in seen:
            seen.add(c.dims)
            out.append(c)
    return tuple(out)


@functools.lru_cache(maxsize=None)
def _accepts_kwarg(fn, name: str) -> bool:
    """Does ``fn`` (an executor method) take a ``name`` kwarg?
    Pre-config/pre-fusion third-party overrides — 5-argument
    ``_execute``, ``vmem_bytes(self, spec)`` — keep their old
    signatures and are called without the newer kwargs."""
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):            # builtins/C callables
        return False
    return (name in params
            or any(p.kind is inspect.Parameter.VAR_KEYWORD
                   for p in params.values()))


def _accepts_config(fn) -> bool:
    """Back-compat alias for ``_accepts_kwarg(fn, "config")``."""
    return _accepts_kwarg(fn, "config")


class Executor:
    """One registered convolution algorithm: capabilities + execution.

    Subclasses override the declarations; the planner only ever talks to
    these methods, so a third-party executor participates in forced
    resolution, measured autotuning, heuristic negotiation and the
    cheapest-supported tier with zero planner changes.
    """

    #: registry identity (also the persisted-cache algorithm string)
    name: str = ""
    #: raw conv callable ``fn(x, w, stride=, padding=, ...)`` — the
    #: pre-registry ``ALGORITHMS`` surface, still exposed via the
    #: ``algorithms()`` view for benchmarks that time bare kernels
    fn: Optional[Callable] = None
    #: ConvSpec.dtype strings this executor accepts
    dtypes: Tuple[str, ...] = ("float32", "bfloat16")
    #: accumulation behavior for the channel contraction
    accum: str = "float32"
    #: can execute groups > 1 specs exactly
    supports_groups: bool = False
    #: the bias/ReLU epilogue runs inside the kernel (no extra HBM trip)
    fuses_epilogue: bool = False
    #: forward the planner's interpret flag (Pallas executors)
    takes_interpret: bool = False
    #: names of the launch-config dims this executor can tune; () means
    #: untunable (library/XLA executors — one empty config, nothing to
    #: sweep)
    tunable: Tuple[str, ...] = ()

    # -- capability ------------------------------------------------------
    def supports(self, spec) -> Tuple[bool, str]:
        """Can this executor run ``spec`` exactly (ignoring speed)?

        Common gates (dtype, groups) live here; geometry-specific limits
        go in ``_supports``.
        """
        if spec.dtype not in self.dtypes:
            return False, (f"dtype {spec.dtype} not in {self.name}'s "
                           f"declared dtypes {self.dtypes}")
        if spec.groups != 1 and not self.supports_groups:
            return False, (f"no grouped-conv support (groups={spec.groups}); "
                           f"lax feature_group_count is the executor")
        fusable = self.fusions(spec)
        if spec.fused_add != "none" and "add" not in fusable:
            return False, (f"{self.name} does not fuse a residual add "
                           f"(declared fusions for this spec: "
                           f"{list(fusable) or 'none'})")
        if spec.fused_pool and "pool" not in fusable:
            return False, (f"{self.name} does not fuse pool "
                           f"{spec.fused_pool!r} (declared fusions for "
                           f"this spec: {list(fusable) or 'none'})")
        return self._supports(spec)

    def _supports(self, spec) -> Tuple[bool, str]:
        return True, "generic algorithm"

    def fusions(self, spec) -> Tuple[str, ...]:
        """Cross-layer fusions ("add", "pool") this executor can absorb
        for ``spec``'s geometry (DESIGN.md §10).

        Non-fusing executors take every fusion for free: ``execute``
        applies the residual add / pool as XLA ops after the bare conv,
        exactly as the unfused graph would have — so folding nodes into
        their specs is always numerically safe.  In-kernel
        (``fuses_epilogue``) executors must opt in per fusion kind and
        handle the operands inside ``_execute``.
        """
        if self.fuses_epilogue:
            return ()
        return ("add", "pool")

    # -- tuning space (DESIGN.md §9) -------------------------------------
    def configs(self, spec) -> Tuple[LaunchConfig, ...]:
        """Ordered candidate launch configs for ``spec``.

        Candidate 0 is the historical hard-coded geometry (the safe
        default the kernel shipped with); candidates are clamped to the
        spec's dims but NOT yet feasibility-pruned — pair with
        ``config_supports`` (the measured sweep and ``default_config``
        both do).  Untunable executors expose one empty config.
        """
        return (LaunchConfig(),)

    def config_supports(self, spec, config) -> Tuple[bool, str]:
        """Can this executor run ``spec`` under ``config`` exactly?

        Common gates (declared tunable dims, positive values, the VMEM
        budget via ``vmem_bytes``) live here; geometry-specific rules go
        in ``_config_supports``.
        """
        config = LaunchConfig.of(config)
        unknown = [k for k, _ in config.dims if k not in self.tunable]
        if unknown:
            return False, (f"{self.name} has no tunable dim(s) {unknown} "
                           f"(tunable: {list(self.tunable) or 'none'})")
        bad = [(k, v) for k, v in config.dims if v < 1]
        if bad:
            return False, f"launch dims must be >= 1; got {bad}"
        ok, why = self._config_supports(spec, config)
        if not ok:
            return False, why
        # pre-config third-party overrides (vmem_bytes(self, spec)) are
        # consulted without the config argument
        if _accepts_config(type(self).vmem_bytes):
            need = self.vmem_bytes(spec, config)
        else:
            need = self.vmem_bytes(spec)
        if need is not None and need > FUSED_VMEM_BUDGET:
            return False, (f"config [{config.key()}] working set "
                           f"{need / 2**20:.1f} MB > "
                           f"{FUSED_VMEM_BUDGET / 2**20:.0f} MB VMEM budget")
        return True, why

    def _config_supports(self, spec, config) -> Tuple[bool, str]:
        return True, "config geometry ok"

    def config_cost(self, spec, config) -> float:
        """Abstract cost of running ``spec`` under ``config`` — only has
        to *rank* candidates (``default_config`` minimizes it; ties keep
        the earliest candidate).  Tunable executors model grid-step
        count (bigger feasible blocks = fewer steps = fuller MXU)."""
        return 0.0

    def default_config(self, spec) -> LaunchConfig:
        """Model-chosen launch config absent measurement: the cheapest
        VMEM-feasible candidate by ``config_cost`` (stable min — ties
        keep candidate 0, the historical geometry)."""
        cands = self.configs(spec)
        feasible = [c for c in cands if self.config_supports(spec, c)[0]]
        if not feasible:
            return cands[0]
        return min(feasible, key=lambda c: self.config_cost(spec, c))

    # -- negotiation inputs ----------------------------------------------
    def heuristic_claim(self, spec, backend: str
                        ) -> Optional[Tuple[int, str]]:
        """``(score, reason)`` claim on the paper's regions, or None.

        Only consulted when ``supports(spec)`` holds; the highest score
        among supporting executors wins the heuristic tier.
        """
        return None

    def cost(self, spec) -> float:
        """Abstract cost for the cheapest-supported tier: the executor's
        arithmetic (``flop_cost``) plus its extra HBM traffic, weighted
        by ``_COST_PER_HBM_BYTE``."""
        return (self.flop_cost(spec)
                + _COST_PER_HBM_BYTE * self.extra_hbm_bytes(spec))

    def flop_cost(self, spec) -> float:
        """Arithmetic term: direct-conv MACs (identical for every exact
        executor; transform-based executors override)."""
        n, oh, ow, m = spec.out_shape
        kh, kw, cpg, _ = spec.filter_shape
        return 2.0 * n * oh * ow * m * kh * kw * cpg

    def extra_hbm_bytes(self, spec) -> float:
        """HBM traffic beyond reading inputs and writing the output
        once (materialized temporaries, transform tensors, ...)."""
        return 0.0

    def vmem_bytes(self, spec, config=None) -> Optional[int]:
        """Static VMEM working-set estimate under ``config`` (None: the
        default hard-coded geometry), or None when there is no VMEM
        model.  ``config_supports`` prunes candidates through this
        before any measurement happens."""
        return None

    def fallback(self, spec) -> Tuple[str, str]:
        """Closest registered stand-in when this executor is forced but
        cannot run ``spec`` (grouped specs raise instead; see plan())."""
        return "lax", "library conv covers all geometries"

    # -- execution -------------------------------------------------------
    def execute(self, spec, x, w, bias=None, addend=None, interpret=None,
                config=None, quant=None):
        """Run ``spec`` on ``(x, w, bias[, addend])``, epilogue included.

        Operands are cast to the spec dtype first (under a bf16
        precision policy the master weights stay fp32); the contraction
        accumulates per ``accum``.  Non-fusing executors apply the
        bias/ReLU epilogue — and any cross-layer fusion the spec
        carries (residual ``addend``, trailing pool) — as XLA ops after
        the bare conv; ``fuses_epilogue`` executors absorb everything
        in-kernel.  ``config`` is the plan's resolved launch config;
        executors whose ``_execute`` predates the config/fusion era
        (5-argument third-party subclasses) are called without the
        newer kwargs.  ``quant`` is the quantization payload (calibrated
        activation scale) ConvPlan forwards on int8 plans — ignored
        here; int8-declaring executors override ``execute`` and consume
        it.
        """
        dtype = jnp.dtype(spec.dtype)
        x = x if x.dtype == dtype else x.astype(dtype)
        w = w if w.dtype == dtype else w.astype(dtype)
        if bias is not None and bias.dtype != dtype:
            bias = bias.astype(dtype)
        if spec.fused_add != "none" and addend is None:
            raise ValueError(f"fused-add spec {spec.key()} needs an addend")
        if addend is not None and addend.dtype != dtype:
            addend = addend.astype(dtype)
        kwargs = {}
        if _accepts_kwarg(type(self)._execute, "config"):
            kwargs["config"] = LaunchConfig.of(config)
        if addend is not None and self.fuses_epilogue:
            if not _accepts_kwarg(type(self)._execute, "addend"):
                raise TypeError(
                    f"executor {self.name!r} declares the 'add' fusion but "
                    f"its _execute takes no addend kwarg")
            kwargs["addend"] = addend
        y = self._execute(spec, x, w, bias, interpret, **kwargs)
        if not self.fuses_epilogue:
            if spec.has_bias:
                y = y + bias
            if spec.fused_add != "none":
                y = y + addend
                if spec.fused_add == "add_relu":
                    y = jnp.maximum(y, 0)
            elif spec.wants_relu:
                y = jnp.maximum(y, 0)
            if spec.fused_pool:
                from repro.kernels import ops
                kind, pkh, pkw, psh, psw, pph, ppw = spec.fused_pool
                y = ops.pool2d(y, kind=kind, window=(pkh, pkw),
                               stride=(psh, psw), padding=(pph, ppw))
        return y

    def _execute(self, spec, x, w, bias, interpret):
        kwargs = {}
        if self.takes_interpret:
            kwargs["interpret"] = interpret
        if spec.groups != 1:
            kwargs["groups"] = spec.groups
        return self.fn(x, w, stride=spec.stride, padding=spec.padding,
                       **kwargs)

    def __repr__(self):
        return (f"<Executor {self.name} dtypes={self.dtypes} "
                f"accum={self.accum} groups={self.supports_groups} "
                f"fused_epilogue={self.fuses_epilogue}>")


# ---------------------------------------------------------------------------
# registry

_REGISTRY: Dict[str, Executor] = {}


def register(executor: Executor) -> Executor:
    """Add an executor to the menu (third-party entry point).

    The name becomes resolvable everywhere at once: ``conv2d``'s
    ``algorithm=`` strings, forced plans, measured autotuning, heuristic
    negotiation and persisted cache entries.
    """
    name = executor.name
    if not name or not isinstance(name, str):
        raise ValueError(f"executor needs a non-empty string name; "
                         f"got {name!r}")
    if name in _REGISTRY:
        raise ValueError(f"executor {name!r} already registered; "
                         f"unregister it first to replace it")
    if executor.fn is None and type(executor)._execute is Executor._execute:
        # fail at registration, not deep inside a jitted trace when the
        # default _execute calls a None fn
        raise ValueError(f"executor {name!r} must set `fn` or override "
                         f"`_execute`")
    _REGISTRY[name] = executor
    return executor


def unregister(name: str) -> Executor:
    """Remove a registered executor (returns it); unknown names raise."""
    ex = _REGISTRY.pop(name, None)
    if ex is None:
        raise KeyError(f"unknown algorithm {name!r}; "
                       f"registered: {sorted(_REGISTRY)}")
    return ex


def get(name: str) -> Executor:
    ex = _REGISTRY.get(name)
    if ex is None:
        raise KeyError(f"unknown algorithm {name!r}; "
                       f"registered: {sorted(_REGISTRY)}")
    return ex


def capable(name: str, spec) -> bool:
    """Is ``name`` a registered executor whose declarations cover
    ``spec``?  The one rule every stale-cache reader applies: persisted
    entries (measured winners, graph plans) naming unregistered or
    no-longer-capable executors must be dropped, never served."""
    ex = _REGISTRY.get(name)
    return ex is not None and ex.supports(spec)[0]


def names() -> Tuple[str, ...]:
    """Registered executor names, in registration order."""
    return tuple(_REGISTRY)


def registered() -> Dict[str, Executor]:
    """Snapshot of the registry (mutating it does not unregister)."""
    return dict(_REGISTRY)


class _AlgorithmsView(_MappingABC):
    """Read-only ``{name: bare conv callable}`` view of the registry —
    the pre-registry ``cuconv.ALGORITHMS`` surface, kept for callers
    that time or compose the raw executor functions.  Executors that
    expose no bare callable (``fn is None`` — legal for third-party
    entries that only implement ``_execute``) are simply absent from
    the view, keeping the Mapping contract (iteration never yields a
    key that ``[]`` would refuse)."""

    def __getitem__(self, name: str) -> Callable:
        fn = get(name).fn
        if fn is None:
            raise KeyError(f"executor {name!r} exposes no bare callable")
        return fn

    def __iter__(self):
        return (n for n, e in _REGISTRY.items() if e.fn is not None)

    def __len__(self):
        return sum(1 for e in _REGISTRY.values() if e.fn is not None)

    def __repr__(self):
        return f"ALGORITHMS({', '.join(self)})"


#: back-compat mapping (``from repro.core import ALGORITHMS``)
ALGORITHMS = _AlgorithmsView()


def algorithms() -> _AlgorithmsView:
    return ALGORITHMS


# ---------------------------------------------------------------------------
# negotiation

def negotiate(spec, backend: str) -> Tuple[str, str, str]:
    """Pick an executor for ``spec`` from capability declarations alone.

    Returns ``(name, source, reason)``: the highest-scoring heuristic
    claim among supporting executors (``source="heuristic"``, the
    paper's regions), else the cheapest supported executor by cost model
    (``source="cost"``).  No executor supporting the spec at all is an
    error that names every executor's refusal — the signal a precision
    policy or spec asks for something the menu cannot serve.
    """
    best_claim = None          # (score, name, reason); first-registered wins ties
    cheapest = None            # (cost, name)
    refusals = []
    for ex in _REGISTRY.values():
        ok, why = ex.supports(spec)
        if not ok:
            refusals.append(f"{ex.name}: {why}")
            continue
        claim = ex.heuristic_claim(spec, backend)
        if claim is not None and (best_claim is None
                                  or claim[0] > best_claim[0]):
            best_claim = (claim[0], ex.name, claim[1])
        c = ex.cost(spec)
        if cheapest is None or c < cheapest[0]:
            cheapest = (c, ex.name)
    if best_claim is not None:
        return best_claim[1], "heuristic", best_claim[2]
    if cheapest is not None:
        return (cheapest[1], "cost",
                f"cheapest supported executor (cost {cheapest[0]:.3g})")
    raise ValueError(
        f"no registered executor supports spec {spec.key()}; "
        + "; ".join(refusals))


def supporting(spec) -> Tuple[str, ...]:
    """Names of every registered executor that can run ``spec`` exactly
    (the measured autotuner's default candidate set)."""
    return tuple(n for n, ex in _REGISTRY.items() if ex.supports(spec)[0])


# ---------------------------------------------------------------------------
# built-in executors (the paper's algorithm family)

class LaxExecutor(Executor):
    """XLA's native convolution — the cuDNN stand-in of the paper's
    comparison, and the only executor for grouped/depthwise specs."""
    name = "lax"
    supports_groups = True

    def _supports(self, spec):
        if spec.groups != 1:
            return True, (f"grouped conv (groups={spec.groups}): library "
                          f"feature_group_count")
        return True, "library conv covers all geometries"

    def heuristic_claim(self, spec, backend):
        if spec.groups != 1:
            return 95, (f"grouped conv (groups={spec.groups}): library "
                        f"feature_group_count")
        if not spec.unit_stride:
            # a low claim: any capable kernel claiming the strided
            # region outranks it, so winning here means nothing else did
            return 40, ("strided conv: library kernel off-TPU"
                        if backend != "tpu"
                        else "strided conv: library kernel "
                        "(no higher-priority claim)")
        return None

    def _execute(self, spec, x, w, bias, interpret):
        from repro.core import cuconv
        return cuconv.conv_lax(x, w, stride=spec.stride,
                               padding=spec.padding, groups=spec.groups)


class Im2colExecutor(Executor):
    """Explicit patch matrix + one GEMM (cuDNN "GEMM" variant); pays
    KH*KW-fold input duplication through HBM."""
    name = "im2col"

    def extra_hbm_bytes(self, spec):
        n, oh, ow, _ = spec.out_shape
        kh, kw, cpg, _ = spec.filter_shape
        itemsize = jnp.dtype(spec.dtype).itemsize
        # patch matrix written then re-read by the GEMM
        return 2.0 * n * oh * ow * kh * kw * cpg * itemsize


class WinogradExecutor(Executor):
    """F(2x2, 3x3) minimal filtering — the paper's strongest competitor
    in the large-3x3 region."""
    name = "winograd"

    def _supports(self, spec):
        if spec.filter_shape[:2] != (3, 3) or not spec.unit_stride:
            return False, "Winograd F(2x2,3x3) needs 3x3 stride-1"
        return True, "3x3 stride-1: Winograd region"

    def heuristic_claim(self, spec, backend):
        if not _is_small(spec):
            return 70, "large 3x3: Winograd region in the paper"
        return None

    def flop_cost(self, spec):
        # 2.25x fewer multiplies than direct (the traffic penalty from
        # extra_hbm_bytes rides on top, undivided)
        return super().flop_cost(spec) / 2.25

    def extra_hbm_bytes(self, spec):
        n, oh, ow, m = spec.out_shape
        c = spec.in_shape[3]
        itemsize = jnp.dtype(spec.dtype).itemsize
        # 16 positions per 2x2 output block: the gathered input tiles /
        # written output tiles transit at the spec dtype; the Winograd-
        # domain tensors (V, M) genuinely stay f32 (4 bytes)
        tiles = n * ((oh + 1) // 2) * ((ow + 1) // 2) * 16
        return tiles * (c + m) * (itemsize + 4.0)

    def _execute(self, spec, x, w, bias, interpret):
        from repro.core.winograd import conv_winograd
        return conv_winograd(x, w, 1, spec.padding)


class TwoStageExecutor(Executor):
    """Faithful paper pipeline (XLA): stage-1 temporaries materialized
    (KH*KW, N, OH, OW, M), stage-2 sum."""
    name = "cuconv_two_stage"

    def extra_hbm_bytes(self, spec):
        n, oh, ow, m = spec.out_shape
        kh, kw = spec.filter_shape[:2]
        # f32 temporaries written by stage 1, re-read by stage 2
        return 2.0 * kh * kw * n * oh * ow * m * 4


class CuconvExecutor(Executor):
    """Beyond-paper fused tap accumulation (XLA, no temporaries) — the
    paper's "work-fusion" future work realized."""
    name = "cuconv"

    def heuristic_claim(self, spec, backend):
        if not spec.unit_stride:
            return None
        if spec.is_1x1:
            return 60, "1x1: single GEMM, no stage 2 (best region)"
        if _is_small(spec):
            return 60, "small batch/spatial: cuConv region"
        if spec.filter_shape[:2] == (3, 3):
            return None                    # Winograd's region in the paper
        return 20, "default cuConv region"


# Tiled-GEMM launch candidates shared by the 1x1 and two-stage Pallas
# kernels: (tp, tm, tc) = pixel / out-channel / contraction tiles.
# Candidate 0 is the historical hard-coded geometry; the rest widen or
# shrink each axis (clamped per spec, so small paper shapes dedupe).
_GEMM_TILES = (
    (256, 128, 512),
    (512, 256, 512),
    (256, 512, 512),
    (128, 128, 256),
    (512, 128, 1024),
    (128, 64, 128),
)


def _gemm_tile_configs(p: int, m: int, c: int) -> Tuple[LaunchConfig, ...]:
    return _dedup_configs(
        {"tp": min(tp, p), "tm": min(tm, m), "tc": min(tc, c)}
        for tp, tm, tc in _GEMM_TILES)


def _gemm_tile_vmem(config: LaunchConfig, itemsize: int) -> int:
    """Live-block model of one tiled GEMM step: x/w input blocks double
    buffered, output block plus its f32 VMEM accumulator."""
    tp = config.get("tp", 256)
    tm = config.get("tm", 128)
    tc = config.get("tc", 512)
    return 2 * itemsize * (tp * tc + tc * tm) + (itemsize + 4) * tp * tm


def _gemm_tile_steps(p: int, m: int, c: int, config: LaunchConfig) -> float:
    """Grid-step count of the tiled GEMM under ``config`` (the ranking
    ``config_cost`` minimizes)."""
    tp = min(config.get("tp", 256), p)
    tm = min(config.get("tm", 128), m)
    tc = min(config.get("tc", 512), c)
    return (-(-p // tp)) * (-(-m // tm)) * (-(-c // tc))


class Conv1x1PallasExecutor(Executor):
    """Dedicated 1x1 GEMM Pallas kernel: all N*H*W pixels MXU-tiled —
    the paper's best-case region on its natural kernel."""
    name = "conv1x1_pallas"
    takes_interpret = True
    tunable = ("tp", "tm", "tc")

    def _supports(self, spec):
        if (not spec.is_1x1 or not spec.unit_stride
                or spec.padding != (0, 0)):
            return False, "conv1x1 kernel needs 1x1 filter, stride 1, pad 0"
        return True, "1x1 GEMM kernel (all pixels MXU-tiled)"

    def heuristic_claim(self, spec, backend):
        if backend == "tpu" and spec.epilogue == "none":
            # no epilogue to fuse: this kernel tiles all N*H*W pixels
            # onto the MXU (the fused kernel only fills OW rows per step)
            return 90, "1x1: dedicated GEMM kernel"
        return None

    def _gemm_dims(self, spec):
        n, h, w, c = spec.in_shape
        return n * h * w, spec.filter_shape[3], c

    def configs(self, spec):
        return _gemm_tile_configs(*self._gemm_dims(spec))

    def vmem_bytes(self, spec, config=None):
        return _gemm_tile_vmem(LaunchConfig.of(config),
                               jnp.dtype(spec.dtype).itemsize)

    def config_cost(self, spec, config):
        return _gemm_tile_steps(*self._gemm_dims(spec), config)

    def _execute(self, spec, x, w, bias, interpret, config=None):
        from repro.kernels import ops
        cfg = LaunchConfig.of(config)
        return ops.conv1x1(x, w, interpret=interpret,
                           tp=cfg.get("tp", 256), tm=cfg.get("tm", 128),
                           tc=cfg.get("tc", 512))


class TwoStagePallasExecutor(Executor):
    """Faithful two-kernel Pallas pipeline (stride 1): HBM temporaries +
    stage-2 sum — the fused kernel's VMEM-bounded fallback."""
    name = "cuconv_two_stage_pallas"
    takes_interpret = True
    tunable = ("tp", "tm", "tc")

    def _supports(self, spec):
        if not spec.unit_stride:
            return False, "two-stage Pallas kernels are stride-1 only"
        return True, "two-stage Pallas pipeline (bounded VMEM)"

    def extra_hbm_bytes(self, spec):
        n, oh, ow, m = spec.out_shape
        kh, kw = spec.filter_shape[:2]
        return 2.0 * kh * kw * n * oh * ow * m * 4

    def _gemm_dims(self, spec):
        n, oh, ow, m = spec.out_shape
        return n * oh * ow, m, spec.filter_shape[2]

    def configs(self, spec):
        return _gemm_tile_configs(*self._gemm_dims(spec))

    def vmem_bytes(self, spec, config=None):
        return _gemm_tile_vmem(LaunchConfig.of(config),
                               jnp.dtype(spec.dtype).itemsize)

    def config_cost(self, spec, config):
        p, m, c = self._gemm_dims(spec)
        kh, kw = spec.filter_shape[:2]
        return kh * kw * _gemm_tile_steps(p, m, c, config)

    def _execute(self, spec, x, w, bias, interpret, config=None):
        from repro.kernels import ops
        cfg = LaunchConfig.of(config)
        return ops.cuconv_two_stage(x, w, spec.padding, interpret=interpret,
                                    tp=cfg.get("tp", 256),
                                    tm=cfg.get("tm", 128),
                                    tc=cfg.get("tc", 512))


class FusedPallasExecutor(Executor):
    """The fused Pallas TPU kernel: any stride >= 1, per-tap partials
    accumulated in VMEM, bias+ReLU epilogue fused before the single HBM
    write.

    Tuning space: ``tm`` (output-channel tile) x ``rows`` (output rows
    per grid step — the multi-row blocking that lets short-``OW`` paper
    shapes feed the MXU a (rows*OW x C) window instead of one row).
    ``rows >= 2`` is only geometrically valid when ``KH - 1 <= rows*sh``
    (the kernel's two-staged-block halo rule) and ``rows <= OH``; both
    are ``config_supports`` rules, so stale persisted configs from an
    earlier geometry are re-resolved, never served.
    """
    name = "cuconv_pallas"
    fuses_epilogue = True
    takes_interpret = True
    tunable = ("tm", "rows")

    @staticmethod
    def _pool3(spec):
        """``(kind, psh, psw)`` kernel-pool tuple for a fused-pool spec."""
        kind, _, _, psh, psw, _, _ = spec.fused_pool
        return (kind, psh, psw)

    def fusions(self, spec):
        """In-kernel fusions: any residual add; non-overlapping unpadded
        pools whose geometry the multi-row blocking can cover (window ==
        stride, OH/OW divisible by the pool stride)."""
        out = ("add",)
        if spec.fused_pool:
            kind, pkh, pkw, psh, psw, pph, ppw = spec.fused_pool
            _, oh, ow, _ = spec.out_shape
            if ((pkh, pkw) == (psh, psw) and (pph, ppw) == (0, 0)
                    and oh % psh == 0 and ow % psw == 0):
                out = out + ("pool",)
        return out

    def vmem_bytes(self, spec, config=None):
        from repro.kernels.cuconv_fused import vmem_bytes
        cfg = LaunchConfig.of(config)
        itemsize = jnp.dtype(spec.dtype).itemsize
        return vmem_bytes(spec.in_shape, spec.filter_shape,
                          tm=cfg.get("tm", 128), rows=cfg.get("rows", 1),
                          pad=spec.padding, stride=spec.stride,
                          itemsize=itemsize,
                          addend=spec.fused_add != "none",
                          pool=(self._pool3(spec) if spec.fused_pool
                                else None))

    def _supports(self, spec):
        need = self.vmem_bytes(spec)
        if need > FUSED_VMEM_BUDGET:
            return False, (f"fused working set {need / 2**20:.1f} MB "
                           f"> {FUSED_VMEM_BUDGET / 2**20:.0f} MB "
                           f"VMEM budget")
        if spec.fused_pool and not any(
                self.config_supports(spec, c)[0] for c in self.configs(spec)):
            return False, ("no feasible multi-row blocking covers fused "
                           f"pool {spec.fused_pool!r}")
        return True, "fused Pallas kernel fits VMEM"

    def configs(self, spec):
        _, oh, _, m = spec.out_shape
        if spec.fused_pool:
            # rows must tile both the pool stride and OH (candidate 0:
            # one pool window of output rows per grid step)
            psh = spec.fused_pool[3]
            rows_cands = tuple(r for r in (psh, 2 * psh, 4 * psh, 8 * psh)
                               if r <= oh) or (psh,)
        else:
            rows_cands = (1, 2, 4, 8)
        return _dedup_configs(
            {"tm": min(tm, m), "rows": min(rows, oh)}
            for tm in (128, 256, 512)          # candidate 0: tm=128, rows=1
            for rows in rows_cands)

    def _config_supports(self, spec, config):
        rows = config.get("rows", 1)
        _, oh, _, _ = spec.out_shape
        kh = spec.filter_shape[0]
        sh = spec.stride[0]
        if rows > oh:
            return False, (f"rows={rows} exceeds OH={oh} for "
                           f"{spec.key()}")
        if rows > 1 and kh - 1 > rows * sh:
            return False, (f"multi-row blocking needs KH-1 <= rows*sh; "
                           f"got KH={kh}, rows={rows}, sh={sh}")
        if spec.fused_pool:
            psh = spec.fused_pool[3]
            if rows % psh:
                return False, (f"fused pool needs rows % pool stride == 0; "
                               f"got rows={rows}, psh={psh}")
            if oh % rows:
                return False, (f"fused pool needs OH % rows == 0; "
                               f"got OH={oh}, rows={rows}")
            if kh - 1 > rows * sh:
                return False, (f"fused pool rides the multi-row kernel: "
                               f"needs KH-1 <= rows*sh; got KH={kh}, "
                               f"rows={rows}, sh={sh}")
        return True, "config geometry ok"

    def config_cost(self, spec, config):
        n, oh, _, m = spec.out_shape
        kh, kw = spec.filter_shape[:2]
        tm = min(config.get("tm", 128), m)
        rows = max(1, min(config.get("rows", 1), oh))
        return n * (-(-oh // rows)) * (-(-m // tm)) * kh * kw

    def heuristic_claim(self, spec, backend):
        if backend != "tpu":
            return None                    # interpret mode elsewhere
        if spec.has_fusion:
            # outranks every per-layer claim: the folded add/pool stays
            # resident in VMEM instead of round-tripping HBM
            return 85, "cross-layer fusion resident in VMEM"
        if not spec.unit_stride:
            return 80, "strided conv: fused kernel on TPU"
        if spec.is_1x1:
            return 80, "1x1: fused GEMM + epilogue in VMEM"
        if _is_small(spec):
            return 80, "small batch/spatial: cuConv region"
        return None

    def fallback(self, spec):
        if spec.unit_stride:
            # the old kernels/ops.py behaviour: oversized rows take the
            # two-stage Pallas kernels (HBM temporaries, bounded VMEM)
            return ("cuconv_two_stage_pallas",
                    "two-stage kernels bound the VMEM working set")
        return "cuconv", "fused-tap XLA path handles any stride"

    def _execute(self, spec, x, w, bias, interpret, config=None,
                 addend=None):
        # epilogue fused into the kernel: the accumulator takes
        # bias + residual addend + activation (or the fused pool) in
        # VMEM before its single HBM write
        from repro.kernels import ops
        cfg = LaunchConfig.of(config)
        if spec.fused_add != "none":
            relu = spec.fused_add == "add_relu"    # post-add activation
        else:
            relu = spec.wants_relu
        return ops.cuconv_fused(
            x, w, spec.padding, stride=spec.stride,
            bias=bias if spec.has_bias else None,
            activation="relu" if relu else None,
            addend=addend,
            pool=self._pool3(spec) if spec.fused_pool else None,
            tm=cfg.get("tm", 128), rows=cfg.get("rows", 1),
            interpret=interpret)


# Winograd-Pallas launch candidates: (tt, tm, tc) tile triples tried
# under both F(m,3) variants.  Candidate 0 under m=2 is the kernel's
# shipped default geometry; the smaller triples keep the F(4,3) domain
# (36 positions vs 16) inside the VMEM budget on big-channel specs.
_WINO_TILES = (
    (128, 128, 128),
    (256, 128, 128),
    (128, 256, 128),
    (128, 128, 256),
    (128, 128, 64),
    (64, 128, 64),
    (64, 256, 128),
)


class WinogradPallasExecutor(Executor):
    """Tiled Pallas Winograd F(m,3): the whole Winograd domain —
    B^T d B transform, per-position channel GEMMs, fp32 accumulator,
    A^T m A inverse, bias/ReLU/residual epilogue — lives in VMEM inside
    one kernel (kernels/winograd_pallas.py), where the pure-jnp
    ``winograd`` executor round-trips every domain tensor through HBM.

    Tuning space: ``m`` (the F(m,3) variant — F(2x2,3x3) with 16 tile
    positions and 2.25x multiply savings, or F(4x4,3x3) with 36
    positions and 4x savings at looser numerics), ``tt`` (tiles per
    block), ``tm``/``tc`` (output/input channel tiles).  The variant is
    a *config dim*, so ``tune="full"`` arbitrates F(2,3) vs F(4,3) per
    spec and the winner persists like any other launch config.
    """
    name = "winograd_pallas"
    fuses_epilogue = True
    takes_interpret = True
    tunable = ("m", "tt", "tm", "tc")

    def fusions(self, spec):
        # the residual add folds into the in-kernel epilogue (the
        # addend rides the output-tile layout); pool does not
        return ("add",)

    def _supports(self, spec):
        if spec.filter_shape[:2] != (3, 3) or not spec.unit_stride:
            return False, "Winograd F(m,3) needs 3x3 stride-1"
        if not any(self.config_supports(spec, c)[0]
                   for c in self.configs(spec)):
            return False, ("no Winograd tile candidate fits the VMEM "
                           "budget for this spec")
        return True, "3x3 stride-1: tiled Pallas Winograd"

    def _tile_counts(self, spec, fm):
        n, oh, ow, m = spec.out_shape
        return n * (-(-oh // fm)) * (-(-ow // fm)), m, spec.filter_shape[2]

    def configs(self, spec):
        cands = []
        for fm in (2, 4):
            p, m, c = self._tile_counts(spec, fm)
            for tt, tm, tc in _WINO_TILES:
                cands.append({"m": fm, "tt": min(tt, p),
                              "tm": min(tm, m), "tc": min(tc, c)})
        return _dedup_configs(cands)

    def _config_supports(self, spec, config):
        fm = config.get("m", 2)
        if fm not in (2, 4):
            return False, (f"F(m,3) variant must be m=2 or m=4; "
                           f"got m={fm}")
        return True, "config geometry ok"

    def vmem_bytes(self, spec, config=None):
        from repro.kernels.winograd_pallas import vmem_bytes
        cfg = LaunchConfig.of(config)
        return vmem_bytes(spec.in_shape, spec.filter_shape,
                          m=cfg.get("m", 2), tt=cfg.get("tt", 128),
                          tm=cfg.get("tm", 128), tc=cfg.get("tc", 128),
                          itemsize=jnp.dtype(spec.dtype).itemsize,
                          bias=spec.has_bias,
                          addend=spec.fused_add != "none")

    def config_cost(self, spec, config):
        fm = config.get("m", 2)
        p, m, c = self._tile_counts(spec, fm)
        tt = min(config.get("tt", 128), p)
        tm = min(config.get("tm", 128), m)
        tc = min(config.get("tc", 128), c)
        steps = (-(-p // tt)) * (-(-m // tm)) * (-(-c // tc))
        # (m+2)^2 per-position GEMMs per step: F(4,3) quarters the tile
        # count but grows the position count 16 -> 36, netting ~0.56x —
        # the model prefers it wherever it stays VMEM-feasible
        return steps * (fm + 2) ** 2

    def flop_cost(self, spec):
        # 2.25x fewer multiplies than direct under the conservative
        # F(2,3) variant (F(4,3), when tuned in, saves 4x)
        return super().flop_cost(spec) / 2.25

    def extra_hbm_bytes(self, spec):
        n, oh, ow, m = spec.out_shape
        c = spec.filter_shape[2]
        itemsize = jnp.dtype(spec.dtype).itemsize
        p = n * ((oh + 1) // 2) * ((ow + 1) // 2)
        # gathered input-tile tensor + output-tile tensor (written, then
        # re-read by the scatter) at the spec dtype; the transformed
        # filters (f32) are small and reused — the Winograd-domain
        # tensors themselves never leave VMEM (the point of the kernel)
        return (2.0 * p * 16 * c * itemsize + 2.0 * 16 * c * m * 4
                + 2.0 * p * 4 * m * itemsize)

    def heuristic_claim(self, spec, backend):
        if backend != "tpu" or spec.has_fusion:
            return None
        if not _is_small(spec):
            return 82, "large 3x3: tiled Pallas Winograd (fig. 6 region)"
        return None

    def _execute(self, spec, x, w, bias, interpret, config=None,
                 addend=None):
        from repro.kernels import ops
        cfg = LaunchConfig.of(config)
        if spec.fused_add != "none":
            relu = spec.fused_add == "add_relu"    # post-add activation
        else:
            relu = spec.wants_relu
        return ops.winograd_fused(
            x, w, spec.padding,
            bias=bias if spec.has_bias else None,
            activation="relu" if relu else None,
            addend=addend, m=cfg.get("m", 2), tt=cfg.get("tt", 128),
            tm=cfg.get("tm", 128), tc=cfg.get("tc", 128),
            interpret=interpret)


# Direct-conv launch candidates: (tm, tc) output/input channel tiles.
# Candidate 0 is the kernel's shipped default geometry.
_DIRECT_TILES = (
    (128, 256),
    (128, 128),
    (256, 128),
    (128, 512),
    (256, 256),
    (64, 64),
    (512, 128),
)


class DirectConvExecutor(Executor):
    """Im2col-free direct conv (Li et al. 1610.03618): channel-tiled
    fp32 VMEM accumulation, KH*KW taps unrolled in-kernel, no patch
    matrix and no per-tap HBM temporaries (kernels/direct_conv.py).

    Because the contraction is grid-tiled by ``tc``, the VMEM working
    set is bounded for arbitrarily large C — the memory-efficiency
    lever that makes this the registry's large-C backstop where the
    patch matrix (im2col) and full-C row staging (fused kernel) both
    blow up.  ``extra_hbm_bytes`` is near zero by construction: the
    only re-traffic is re-reading the input once per output-channel
    tile.
    """
    name = "direct"
    takes_interpret = True
    tunable = ("tm", "tc")

    def _supports(self, spec):
        if not any(self.config_supports(spec, c)[0]
                   for c in self.configs(spec)):
            return False, ("no channel-tiled candidate fits the VMEM "
                           "budget (spatial staging too large)")
        return True, "im2col-free direct conv (channel-tiled VMEM)"

    def configs(self, spec):
        m, c = spec.filter_shape[3], spec.filter_shape[2]
        return _dedup_configs({"tm": min(tm, m), "tc": min(tc, c)}
                              for tm, tc in _DIRECT_TILES)

    def vmem_bytes(self, spec, config=None):
        from repro.kernels.direct_conv import vmem_bytes
        cfg = LaunchConfig.of(config)
        return vmem_bytes(spec.in_shape, spec.filter_shape,
                          stride=spec.stride, pad=spec.padding,
                          tm=cfg.get("tm", 128), tc=cfg.get("tc", 256),
                          itemsize=jnp.dtype(spec.dtype).itemsize)

    def config_cost(self, spec, config):
        n = spec.in_shape[0]
        kh, kw, c, m = spec.filter_shape
        tm = min(config.get("tm", 128), m)
        tc = min(config.get("tc", 256), c)
        return n * (-(-m // tm)) * (-(-c // tc)) * kh * kw

    def extra_hbm_bytes(self, spec):
        n, h, w_, c = spec.in_shape
        itemsize = jnp.dtype(spec.dtype).itemsize
        # the input is re-read once per output-channel tile beyond the
        # first (default tm=128) — the whole im2col-free saving
        retiles = -(-spec.filter_shape[3] // 128) - 1
        return float(retiles * n * h * w_ * c * itemsize)

    def heuristic_claim(self, spec, backend):
        if backend != "tpu" or spec.has_fusion or spec.is_1x1:
            return None
        if spec.filter_shape[2] >= 256:
            # a modest claim: wins the large-C region exactly where no
            # higher-priority kernel claims (e.g. the fused kernel's
            # full-C staging refused on VMEM, or large-C strided/5x5
            # shapes), the memory-bound frontier of Li et al.
            return 45, "large-C: im2col-free direct path (Li et al.)"
        return None

    def _execute(self, spec, x, w, bias, interpret, config=None):
        from repro.kernels import ops
        cfg = LaunchConfig.of(config)
        return ops.direct_conv(x, w, spec.padding, stride=spec.stride,
                               tm=cfg.get("tm", 128),
                               tc=cfg.get("tc", 256),
                               interpret=interpret)


class Int8PallasExecutor(Executor):
    """Int8 inference executor: symmetric quantization in, int8 x int8
    -> **int32** accumulation on the MXU integer path, fp32
    requantization in the epilogue (DESIGN.md §13).

    The only executor declaring ``dtypes=("int8",)`` — the quantize
    pass flips eligible conv specs to int8 and negotiation lands here;
    every cache key (autotune configs, graph signatures) is
    dtype-distinct by construction, so int8 tuning never collides with
    the fp plans of the same geometry.

    Scales: weights get **per-output-channel** symmetric scales computed
    from the weight values in-trace (exact, no calibration needed);
    activations use the **per-tensor** calibrated scale riding in the
    plan's ``quant`` payload, falling back to a dynamic in-trace
    ``max|x|/127`` when none rode in (autotune timing, ad-hoc plans).
    Epilogue order: dequantize the int32 accumulator through
    ``x_scale * w_scale[m]``, then bias + residual + activation + pool
    at fp32 — identical shapes and operand dtypes to the fp executors,
    so quantized nodes drop into any graph position.

    Tuning space: the shared tiled-GEMM tiles over the im2col dims
    (N*OH*OW, M, KH*KW*C); int8 tiles are a quarter the bytes of f32,
    so bigger blocks stay VMEM-feasible — the throughput lever the
    ROADMAP's int8 item names.
    """
    name = "cuconv_int8"
    dtypes = ("int8",)
    accum = "int32"
    takes_interpret = True
    tunable = ("tp", "tm", "tc")

    def _supports(self, spec):
        return True, "int8 im2col GEMM, int32 accumulation"

    def heuristic_claim(self, spec, backend):
        if backend == "tpu":
            return 95, "int8: quantized GEMM on the MXU integer path"
        return None

    def extra_hbm_bytes(self, spec):
        # the materialized int8 patch matrix (1 byte/elem)
        n, oh, ow, _ = spec.out_shape
        kh, kw, c, _ = spec.filter_shape
        return float(n * oh * ow * kh * kw * c)

    def _gemm_dims(self, spec):
        n, oh, ow, m = spec.out_shape
        kh, kw, c, _ = spec.filter_shape
        return n * oh * ow, m, kh * kw * c

    def configs(self, spec):
        return _gemm_tile_configs(*self._gemm_dims(spec))

    def vmem_bytes(self, spec, config=None):
        # int8 input blocks double buffered; int32 output block + int32
        # VMEM accumulator
        cfg = LaunchConfig.of(config)
        tp, tm = cfg.get("tp", 256), cfg.get("tm", 128)
        tc = cfg.get("tc", 512)
        return 2 * (tp * tc + tc * tm) + 8 * tp * tm

    def config_cost(self, spec, config):
        return _gemm_tile_steps(*self._gemm_dims(spec), config)

    def execute(self, spec, x, w, bias=None, addend=None, interpret=None,
                config=None, quant=None):
        # full override: the base cast-to-spec-dtype would truncate
        # float operands to int8 — quantization IS the cast here
        from repro.quant import symmetric
        if spec.fused_add != "none" and addend is None:
            raise ValueError(f"fused-add spec {spec.key()} needs an addend")
        f32 = jnp.float32
        x, w = x.astype(f32), w.astype(f32)
        if quant is not None and getattr(quant, "x_scale", 0) > 0:
            x_scale = jnp.asarray(quant.x_scale, f32)
        else:
            x_scale = symmetric.scale_for(symmetric.abs_max(x))
        w_scales = symmetric.channel_scales(w)          # (M,) per-channel
        xq = symmetric.quantize_to_int8(x, x_scale)
        wq = symmetric.quantize_to_int8(w, w_scales)
        acc = self._execute(spec, xq, wq, None, interpret,
                            config=LaunchConfig.of(config))
        # fp32 requantization epilogue: dequantize the int32 accumulator
        # through the outer product of scales, THEN bias/residual/
        # activation/pool at fp32 (base executors' epilogue order)
        y = acc.astype(f32) * (x_scale * w_scales)
        if spec.has_bias:
            y = y + bias.astype(f32)
        if spec.fused_add != "none":
            y = y + addend.astype(f32)
            if spec.fused_add == "add_relu":
                y = jnp.maximum(y, 0)
        elif spec.wants_relu:
            y = jnp.maximum(y, 0)
        if spec.fused_pool:
            from repro.kernels import ops
            kind, pkh, pkw, psh, psw, pph, ppw = spec.fused_pool
            y = ops.pool2d(y, kind=kind, window=(pkh, pkw),
                           stride=(psh, psw), padding=(pph, ppw))
        return y

    def _execute(self, spec, x, w, bias, interpret, config=None):
        # bare int8 conv: int8 patch matrix (zero padding is exact under
        # symmetric quantization) -> tiled int8 GEMM -> int32 accumulator
        from repro.core.cuconv import _pad_input, _tap_views
        from repro.kernels import ops
        cfg = LaunchConfig.of(config)
        kh, kw, c, m = spec.filter_shape
        n, oh, ow, _ = spec.out_shape
        xp = _pad_input(x, *spec.padding)
        patches = jnp.stack(
            _tap_views(xp, kh, kw, oh, ow, spec.stride),
            axis=3).reshape(n * oh * ow, kh * kw * c)
        acc = ops.int8_gemm(patches, w.reshape(kh * kw * c, m),
                            interpret=interpret, tp=cfg.get("tp", 256),
                            tm=cfg.get("tm", 128), tc=cfg.get("tc", 512))
        return acc.reshape(n, oh, ow, m)


def _register_builtins() -> None:
    # registration order == the historical ALGORITHMS order (iteration
    # order is visible to autotune candidates and the quickstart)
    from repro.core import cuconv
    for ex, fn in (
            (LaxExecutor(), cuconv.conv_lax),
            (Im2colExecutor(), cuconv.conv_im2col),
            (WinogradExecutor(), cuconv.conv_winograd_or_fallback),
            (TwoStageExecutor(), cuconv.conv_cuconv_two_stage),
            (Conv1x1PallasExecutor(), cuconv.conv_conv1x1_pallas),
            (TwoStagePallasExecutor(), cuconv.conv_cuconv_two_stage_pallas),
            (CuconvExecutor(), cuconv.conv_cuconv),
            (FusedPallasExecutor(), cuconv.conv_cuconv_pallas),
            (WinogradPallasExecutor(), cuconv.conv_winograd_pallas),
            (DirectConvExecutor(), cuconv.conv_direct)):
        ex.fn = fn
        register(ex)
    # no bare-fn surface: the quantize/dequantize epilogue only makes
    # sense through ConvPlan (the registered-executor path)
    register(Int8PallasExecutor())


_register_builtins()
