"""repro: cuConv-on-TPU framework (JAX + Pallas)."""
__version__ = "1.0.0"
