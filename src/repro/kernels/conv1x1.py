"""1x1 convolution = pointwise GEMM — the paper's best-case fast path.

A 1x1 convolution has a single filter tap, so cuConv stage 1 *is* the
convolution (paper §3: "the second kernel is not necessary").  On TPU this
is a plain tiled matmul on the MXU: (pixels x C) @ (C x M), with all three
dims tiled to VMEM blocks and the C (contraction) grid dim innermost so
the f32 accumulator lives in VMEM scratch across revisits.

Block shape rationale (v5e): 256x512 x-block (512 KB f32), 512x128 w-block
(256 KB), 256x128 acc (128 KB) — three buffers + double buffering stay
well inside the ~16 MB hull; 128-multiples keep the MXU fully fed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import _compat


def _kernel(x_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tp", "tm", "tc", "interpret"))
def conv1x1_gemm(x2d, w, tp=256, tm=128, tc=512, interpret=True):
    """x2d: (P, C) pixels-major; w: (C, M).  Returns (P, M) in x2d.dtype."""
    P, C = x2d.shape
    _, M = w.shape
    (tp, tm, tc), (pp, pm, pc) = _compat.clamp_tiles((P, M, C),
                                                     (tp, tm, tc))
    xp = jnp.pad(x2d, ((0, pp), (0, pc)))
    wp = jnp.pad(w, ((0, pc), (0, pm)))
    grid = ((P + pp) // tp, (M + pm) // tm, (C + pc) // tc)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tp, tc), lambda p, m, c: (p, c)),
            pl.BlockSpec((tc, tm), lambda p, m, c: (c, m)),
        ],
        out_specs=pl.BlockSpec((tp, tm), lambda p, m, c: (p, m)),
        out_shape=jax.ShapeDtypeStruct((P + pp, M + pm), x2d.dtype),
        scratch_shapes=[pltpu.VMEM((tp, tm), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="conv1x1_gemm",
    )(xp, wp)
    return out[:P, :M]
