"""Tap-decomposed depthwise causal conv1d (cuConv's idea in 1D).

Used by the Mamba2 / Jamba SSM blocks (d_conv = 4).  Depthwise conv has
no channel contraction, so taps accumulate on the VPU (elementwise FMA)
instead of the MXU — the decomposition still removes any im2col-style
window materialization: the K shifted views are XLA slices of one padded
buffer, and the kernel accumulates K rank-1-broadcast FMAs per tile with
the output tile resident in VMEM (tap axis innermost, revisited).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import _compat


def _kernel(xs_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += (xs_ref[0].astype(jnp.float32)
                     * w_ref[0].astype(jnp.float32)[None, :])

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tl", "td", "interpret"))
def conv1d_tap(x, w, b=None, tl=512, td=256, interpret=True):
    """Causal depthwise conv1d.  x: (B, L, D); w: (K, D); b: (D,) or None."""
    B, Lx, D = x.shape
    K, _ = w.shape
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    # K shifted views, flattened over (B, L)
    xs = jnp.stack([xp[:, k:k + Lx, :] for k in range(K)], axis=0)
    xs = xs.reshape(K, B * Lx, D)
    P = B * Lx
    tl, td = min(tl, P), min(td, D)
    pp, pd = (-P) % tl, (-D) % td
    xsp = jnp.pad(xs, ((0, 0), (0, pp), (0, pd)))
    wp = jnp.pad(w, ((0, 0), (0, pd)))
    grid = ((P + pp) // tl, (D + pd) // td, K)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tl, td), lambda p, d, k: (k, p, d)),
            pl.BlockSpec((1, td), lambda p, d, k: (k, d)),
        ],
        out_specs=pl.BlockSpec((tl, td), lambda p, d, k: (p, d)),
        out_shape=jax.ShapeDtypeStruct((P + pp, D + pd), x.dtype),
        scratch_shapes=[pltpu.VMEM((tl, td), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="conv1d_tap",
    )(xsp, wp)
    out = out[:P, :D].reshape(B, Lx, D)
    if b is not None:
        out = out + b.astype(out.dtype)
    return out
