"""cuConv stage 1 (faithful): per-tap channel contraction.

The CUDA kernel (`scalar_prods_kernel`) pins one filter row in shared
memory and streams the input rows that reuse it.  TPU mapping: each grid
step pins one filter-tap block F[t] (C_tile x M_tile) in VMEM and streams
a pixel tile of the tap's shifted input view against it on the MXU —
same reuse structure, systolic instead of scalar.

Inputs are the KH*KW shifted views stacked by the wrapper (XLA slices of
the padded input — *not* an im2col matrix; element duplication never hits
HBM as the views alias the same buffer until fused by XLA).
Output: the paper's temporaries (T, P, M) — deliberately materialized,
that is the faithful-memory-behaviour variant benchmarked against the
fused kernel in §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import _compat


def _kernel(xs_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(xs_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tp", "tm", "tc", "interpret"))
def stage1_tap_gemm(xs, w, tp=256, tm=128, tc=512, interpret=True):
    """xs: (T, P, C) stacked shifted views; w: (T, C, M) filter taps.

    Returns the stage-1 temporaries (T, P, M), f32.
    """
    T, P, C = xs.shape
    _, _, M = w.shape
    (tp, tm, tc), (pp, pm, pc) = _compat.clamp_tiles((P, M, C),
                                                     (tp, tm, tc))
    xsp = jnp.pad(xs, ((0, 0), (0, pp), (0, pc)))
    wp = jnp.pad(w, ((0, 0), (0, pc), (0, pm)))
    grid = (T, (P + pp) // tp, (M + pm) // tm, (C + pc) // tc)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tp, tc), lambda t, p, m, c: (t, p, c)),
            pl.BlockSpec((1, tc, tm), lambda t, p, m, c: (t, c, m)),
        ],
        out_specs=pl.BlockSpec((1, tp, tm), lambda t, p, m, c: (t, p, m)),
        out_shape=jax.ShapeDtypeStruct((T, P + pp, M + pm), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tp, tm), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="cuconv_stage1",
    )(xsp, wp)
    return out[:, :P, :M]
