"""Pallas TPU kernels for the perf-critical hot spots (conv + attention).

Each kernel: <name>.py (pl.pallas_call + BlockSpec), wrapped in ops.py,
with a pure-jnp oracle in ref.py.  Validated in interpret mode on CPU.
"""
