"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_ref(x, w, stride=1, padding=(0, 0)):
    """NHWC direct convolution via the platform library op."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride),
        padding=(padding[0],) * 2 if isinstance(padding[0], int) else padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def conv2d_pad_ref(x, w, padding=(0, 0)):
    ph, pw = padding
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding=((ph, ph), (pw, pw)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def conv1x1_ref(x2d, w):
    return (x2d.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x2d.dtype)


def stage1_ref(xs, w):
    """xs: (T, P, C); w: (T, C, M) -> (T, P, M) f32."""
    return jnp.einsum("tpc,tcm->tpm", xs.astype(jnp.float32),
                      w.astype(jnp.float32))


def stage2_ref(temps):
    return jnp.sum(temps.astype(jnp.float32), axis=0)


def conv1d_ref(x, w, b=None):
    """Causal depthwise conv1d.  x: (B, L, D); w: (K, D)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0))).astype(jnp.float32)
    y = sum(xp[:, k:k + x.shape[1], :] * w[k].astype(jnp.float32)
            for k in range(K))
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(x.dtype)


def attention_ref(q, k, v, causal=True):
    """q: (BH, Sq, D); k, v: (BH, Sk, D)."""
    D = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) / (D ** 0.5)
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w.astype(q.dtype), v)
