"""Public jit'd wrappers around the Pallas kernels.

``interpret`` defaults to auto: Python-interpret mode on CPU (this
container), compiled Mosaic on a real TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import (conv1x1 as _c1, cuconv_stage1 as _s1,
                           cuconv_stage2 as _s2, cuconv_fused as _cf,
                           conv1d_tap as _c1d, direct_conv as _dcv,
                           flash_attention as _fa, int8_gemm as _i8,
                           winograd_pallas as _wg)


from repro.core.convspec import normalize_stride as _norm_stride  # one home
from repro.kernels._compat import clamp_tiles  # noqa: F401  (re-export)


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def conv1x1(x, w, interpret=None, tp=256, tm=128, tc=512):
    """x: (N, H, W, C); w: (1, 1, C, M) or (C, M).

    ``tp/tm/tc`` are the GEMM launch tiles (pixels/out-channels/
    contraction); the defaults are the historical hard-coded geometry.
    """
    if w.ndim == 4:
        w = w[0, 0]
    N, H, W_, C = x.shape
    out = _c1.conv1x1_gemm(x.reshape(N * H * W_, C), w, tp=tp, tm=tm, tc=tc,
                           interpret=_auto_interpret(interpret))
    return out.reshape(N, H, W_, -1)


def int8_gemm(x2d, w, interpret=None, tp=256, tm=128, tc=512):
    """x2d: (P, C) int8; w: (C, M) int8.  Returns (P, M) **int32** — the
    raw accumulator; dequantization is the int8 executor's epilogue."""
    return _i8.int8_gemm(x2d, w, tp=tp, tm=tm, tc=tc,
                         interpret=_auto_interpret(interpret))


def cuconv_two_stage(x, w, padding=(0, 0), interpret=None,
                     tp=256, tm=128, tc=512):
    """Faithful two-kernel cuConv (stride 1): HBM temporaries + sum.

    Policy-free executor: which inputs take this path (vs the fused or
    1x1 kernels) is decided by core.convspec.plan, not here.
    ``tp/tm/tc`` thread the launch tiles into stage 1; stage 2 rides the
    same pixel tile but keeps its own out-channel tile default (it is a
    bandwidth-bound reduction — 1-9 % of total time in the paper — and
    its historical default differs from stage 1's).
    """
    from repro.core.cuconv import _tap_views  # shared view builder
    interp = _auto_interpret(interpret)
    N, H, W_, C = x.shape
    KH, KW, _, M = w.shape
    ph, pw = padding
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    OH, OW = H + 2 * ph - KH + 1, W_ + 2 * pw - KW + 1
    views = _tap_views(xp, KH, KW, OH, OW, 1)
    xs = jnp.stack([v.reshape(N * OH * OW, C) for v in views], 0)
    temps = _s1.stage1_tap_gemm(xs, w.reshape(KH * KW, C, M),
                                tp=tp, tm=tm, tc=tc, interpret=interp)
    out = _s2.stage2_tap_sum(temps, tp=tp, interpret=interp)
    return out.reshape(N, OH, OW, M).astype(x.dtype)


def cuconv_fused(x, w, padding=(0, 0), stride=1, bias=None, activation=None,
                 addend=None, pool=None, interpret=None, tm=128, rows=1):
    """Single-kernel fused cuConv, any stride >= 1, optional fused
    bias+activation epilogue.

    Policy-free executor: VMEM-budget fallback and algorithm choice live
    in core.convspec.plan — calling this directly always runs the fused
    kernel.  ``tm``/``rows`` are its launch config (output-channel tile,
    output rows per grid step; see kernels/cuconv_fused.py).  ``addend``
    (residual second operand) and ``pool`` (``(kind, psh, psw)``
    non-overlapping pool) are the cross-layer fusions of DESIGN.md §10,
    executed in VMEM before the single output write.
    """
    return _cf.cuconv_fused(x, w, bias, stride=_norm_stride(stride),
                            padding=tuple(padding), activation=activation,
                            addend=addend,
                            pool=tuple(pool) if pool is not None else None,
                            tm=tm, rows=rows,
                            interpret=_auto_interpret(interpret))


def winograd_fused(x, w, padding=(1, 1), bias=None, activation=None,
                   addend=None, m=2, tt=128, tm=128, tc=128,
                   interpret=None):
    """Tiled Pallas Winograd F(m,3) conv (3x3, stride 1) with fused
    bias/activation/residual epilogue.

    Policy-free executor: the F(m,3) variant ``m`` and the ``tt/tm/tc``
    tiles are the winograd_pallas launch config (core.convspec.plan
    owns which specs take this path; see kernels/winograd_pallas.py).
    """
    return _wg.winograd_fused(x, w, tuple(padding), bias=bias,
                              activation=activation, addend=addend,
                              m=m, tt=tt, tm=tm, tc=tc,
                              interpret=_auto_interpret(interpret))


def direct_conv(x, w, padding=(0, 0), stride=(1, 1), tm=128, tc=256,
                interpret=None):
    """Im2col-free direct conv (Li et al. 1610.03618): channel-tiled
    fp32 VMEM accumulation, no patch-matrix materialization.  Any
    stride; ``tm/tc`` are the direct executor's launch config."""
    return _dcv.direct_conv(x, w, tuple(padding), _norm_stride(stride),
                            tm=tm, tc=tc,
                            interpret=_auto_interpret(interpret))


def pool2d(x, kind="max", window=(2, 2), stride=(2, 2), padding=(0, 0)):
    """Windowed max/avg pooling over NHWC (the graph IR's pool executor).

    Avg pooling divides by the full window size (padding counts as
    zeros), matching ``lax.avg_pool``-style count_include_pad semantics.
    """
    kh, kw = window
    sh, sw = stride
    ph, pw = padding
    dims, strides = (1, kh, kw, 1), (1, sh, sw, 1)
    pads = ((0, 0), (ph, ph), (pw, pw), (0, 0))
    if kind == "max":
        # init value in the operand dtype so bf16 programs (precision
        # policies) pool without an implicit f64 promotion error
        return jax.lax.reduce_window(x, jnp.asarray(-jnp.inf, x.dtype),
                                     jax.lax.max, dims, strides, pads)
    if kind == "avg":
        s = jax.lax.reduce_window(x, jnp.zeros((), x.dtype), jax.lax.add,
                                  dims, strides, pads)
        return s / (kh * kw)
    raise ValueError(f"pool kind must be 'max' or 'avg'; got {kind!r}")


def conv1d_causal(x, w, b=None, interpret=None):
    return _c1d.conv1d_tap(x, w, b, interpret=_auto_interpret(interpret))


def flash_attention(q, k, v, causal=True, interpret=None):
    """q: (B, Sq, H, D) or (BH, Sq, D); GQA KV broadcast handled here."""
    interp = _auto_interpret(interpret)
    if q.ndim == 4:
        B, Sq, H, D = q.shape
        KVH = k.shape[2]
        if KVH != H:
            rep = H // KVH
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
        kf = k.transpose(0, 2, 1, 3).reshape(B * H, -1, D)
        vf = v.transpose(0, 2, 1, 3).reshape(B * H, -1, D)
        out = _fa.flash_attention(qf, kf, vf, causal=causal, interpret=interp)
        return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
    return _fa.flash_attention(q, k, v, causal=causal, interpret=interp)
