"""Pallas API shims and shared kernel-geometry helpers.

`pltpu.CompilerParams` was `pltpu.TPUCompilerParams` before jax 0.5;
resolve whichever this jaxlib provides so kernels are version-portable.

`clamp_tiles` is the one home of the tile-clamp + pad arithmetic that
every Pallas wrapper used to copy-paste (`tm = min(tm, M)`,
`pm = (-M) % tm`); `kernels/ops.py` re-exports it for callers outside
the kernel package.
"""
from typing import Sequence, Tuple

from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) or getattr(
    _pltpu, "TPUCompilerParams")


def clamp_tiles(dims: Sequence[int], tiles: Sequence[int]
                ) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Clamp tile sizes to their dims and derive the pad-to-multiple.

    Returns ``(clamped, pads)`` where ``clamped[i] = min(tiles[i],
    dims[i])`` and ``pads[i] = (-dims[i]) % clamped[i]`` — so
    ``dims[i] + pads[i]`` is the padded extent and
    ``(dims[i] + pads[i]) // clamped[i]`` the grid size along that axis.
    Non-positive tile sizes are a caller bug and raise.
    """
    if len(dims) != len(tiles):
        raise ValueError(f"{len(dims)} dims but {len(tiles)} tile sizes")
    clamped, pads = [], []
    for d, t in zip(dims, tiles):
        t = int(t)
        if t < 1:
            raise ValueError(f"tile sizes must be >= 1; got {tiles}")
        t = min(t, int(d))
        clamped.append(t)
        pads.append((-int(d)) % t)
    return tuple(clamped), tuple(pads)
