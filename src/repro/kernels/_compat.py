"""Pallas API shims across jax versions.

`pltpu.CompilerParams` was `pltpu.TPUCompilerParams` before jax 0.5;
resolve whichever this jaxlib provides so kernels are version-portable.
"""
from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) or getattr(
    _pltpu, "TPUCompilerParams")
