"""Im2col-free direct convolution with channel-tiled VMEM accumulation.

The explicit-GEMM path (cuDNN "GEMM", our im2col executor) buys one big
MXU matmul by materializing the KH*KW-duplicated patch matrix through
HBM — ``2 * N*OH*OW*KH*KW*C * itemsize`` of extra traffic, the exact
overhead Li et al. ("A Memory-Efficient Direct Convolution...",
arXiv:1610.03618) eliminate.  This kernel is that memory-efficiency
lever as a Pallas executor: no patch matrix, no per-tap HBM
temporaries — the input is read once per output-channel tile, and the
KH*KW tap contributions for one *channel tile* accumulate into an fp32
VMEM scratch across contraction grid steps.

Grid: ``(N, M/tm, C/tc)`` with the channel contraction innermost
("arbitrary").  Each step stages one image's padded spatial extent for
a ``tc``-channel slice plus the matching (KH, KW, tc, tm) filter
block, unrolls the KH*KW taps as strided in-register windows feeding
``(OH*OW x tc) @ (tc x tm)`` MXU matmuls, and writes the output block
once on the final channel step.  Because C is tiled, the VMEM working
set is bounded no matter how many input channels the spec has — the
large-C region where the full-C row staging of the fused kernel and
the patch matrix of im2col both blow up.

Tuning dims (the direct executor's launch-config space): ``tm``
(output-channel tile), ``tc`` (input-channel tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import _compat


def _make_kernel(KH, KW, OH, OW, sh, sw):
    def kernel(x_ref, w_ref, o_ref, acc_ref):
        c = pl.program_id(2)
        xb = x_ref[0]                           # (Hp, Wp, tc)
        wb = w_ref[...]                         # (KH, KW, tc, tm)
        part = None
        for i in range(KH):
            for j in range(KW):
                win = xb[i:i + (OH - 1) * sh + 1:sh,
                         j:j + (OW - 1) * sw + 1:sw, :]   # (OH, OW, tc)
                t = jnp.dot(win.reshape(OH * OW, win.shape[-1]), wb[i, j],
                            preferred_element_type=jnp.float32)
                part = t if part is None else part + t

        @pl.when(c == 0)
        def _init():
            acc_ref[...] = part

        @pl.when(c > 0)
        def _accumulate():
            acc_ref[...] += part

        @pl.when(c == pl.num_programs(2) - 1)
        def _done():
            o_ref[0] = acc_ref[...].reshape(
                OH, OW, acc_ref.shape[-1]).astype(o_ref.dtype)

    return kernel


def vmem_bytes(in_shape, filter_shape, stride=(1, 1), pad=(0, 0),
               tm=128, tc=256, itemsize=4):
    """Live-block VMEM model of one grid step: the channel-sliced image
    and filter blocks double buffered, plus the fp32 accumulator and the
    output block."""
    _, H, W_, _ = in_shape
    KH, KW, _, _ = filter_shape
    Hp, Wp = H + 2 * pad[0], W_ + 2 * pad[1]
    OH = (Hp - KH) // stride[0] + 1
    OW = (Wp - KW) // stride[1] + 1
    return int(2 * (Hp * Wp * tc + KH * KW * tc * tm) * itemsize
               + OH * OW * tm * (4 + itemsize))


@functools.partial(jax.jit, static_argnames=(
    "padding", "stride", "tm", "tc", "interpret"))
def direct_conv(x, w, padding=(0, 0), stride=(1, 1), tm=128, tc=256,
                interpret=True):
    """x: (N, H, W, C) NHWC; w: (KH, KW, C, M) HWIO; any stride.

    Bare conv (no epilogue — the direct executor is non-fusing, so
    bias/activation/fusions apply as XLA ops downstream).  Returns
    (N, OH, OW, M) in ``x.dtype``.
    """
    N, H, W_, C = x.shape
    KH, KW, _, M = w.shape
    ph, pw = padding
    sh, sw = stride
    Hp, Wp = H + 2 * ph, W_ + 2 * pw
    OH = (Hp - KH) // sh + 1
    OW = (Wp - KW) // sw + 1
    (tm, tc), (pm, pc) = _compat.clamp_tiles((M, C), (tm, tc))
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, pc)))
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, pc), (0, pm)))
    grid = (N, (M + pm) // tm, (C + pc) // tc)
    out = pl.pallas_call(
        _make_kernel(KH, KW, OH, OW, sh, sw),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, tc), lambda n, mo, c: (n, 0, 0, c)),
            pl.BlockSpec((KH, KW, tc, tm), lambda n, mo, c: (0, 0, c, mo)),
        ],
        out_specs=pl.BlockSpec((1, OH, OW, tm),
                               lambda n, mo, c: (n, 0, 0, mo)),
        out_shape=jax.ShapeDtypeStruct((N, OH, OW, M + pm), x.dtype),
        scratch_shapes=[pltpu.VMEM((OH * OW, tm), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="direct_conv",
    )(xp, wp)
    return out[..., :M]
