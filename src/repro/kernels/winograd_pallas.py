"""Tiled Pallas Winograd F(m, 3) convolution — one kernel, VMEM-resident
Winograd domain.

The pure-jnp baseline (core/winograd.py) materializes every Winograd-
domain tensor through HBM: the transformed input V ((m+2)^2/m^2 times
the input size — 4x for F(2,3)), the per-position products, and the
untransformed output tiles.  This kernel keeps the whole domain in
VMEM: each grid step stages a block of ``tt`` input tiles, runs the
B^T d B transform in-register (the transform matrices are tiny sparse
constants — unrolled scalar-multiply/adds on the VPU, no MXU), feeds
the (m+2)^2 per-position ``(tt x tc) @ (tc x tm)`` channel GEMMs into
an fp32 VMEM accumulator across contraction steps, and on the final
channel step applies the A^T m A inverse transform plus the fused
bias / residual-add / ReLU epilogue before the single HBM write.

Grid: ``(tiles/tt, M/tm, C/tc)`` with the contraction innermost
("arbitrary") so the accumulator survives revisits — the same layout
discipline as conv1x1.py.  Tile tensors are laid out position-major
``((m+2)^2, tiles, C)`` so each per-position GEMM is a plain 2-D
``jnp.dot`` on the MXU.

Tuning dims (the winograd_pallas executor's launch-config space):
``m`` (F(m,3) variant, 2 or 4), ``tt`` (tiles per block), ``tm``
(output-channel tile), ``tc`` (input-channel tile).

The filter transform U = G g G^T is computed once outside the kernel
(it is (m+2)^2 x C x M — small, reused by every tile block) at f32;
the in-kernel domain math is f32 regardless of operand dtype, so bf16
inputs keep fp32 Winograd accuracy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.winograd import matrices, transform_filters
from repro.kernels import _compat


def _lincomb(mat, rows):
    """``out[i] = sum_j mat[i, j] * rows[j]`` with zero entries skipped —
    the transform matrices are sparse small constants, so the transforms
    are a handful of VPU scalar-multiply/adds, never an MXU matmul."""
    out = []
    for i in range(mat.shape[0]):
        acc = None
        for j in range(mat.shape[1]):
            coef = float(mat[i, j])
            if coef == 0.0:
                continue
            term = rows[j] if coef == 1.0 else rows[j] * coef
            acc = term if acc is None else acc + term
        out.append(acc)
    return out


def _make_kernel(m, has_bias, has_add, activation):
    a = m + 2
    R = a * a
    BT, _, AT = matrices(m)

    def kernel(*refs):
        refs = list(refs)
        d_ref, u_ref = refs[0], refs[1]
        pos = 2
        b_ref = refs[pos] if has_bias else None
        pos += 1 if has_bias else 0
        ad_ref = refs[pos] if has_add else None
        pos += 1 if has_add else 0
        o_ref, acc_ref = refs[pos], refs[pos + 1]

        c = pl.program_id(2)
        d = d_ref[...].astype(jnp.float32)          # (R, tt, tc)
        # B^T d B over the two a-length tile axes (unrolled, sparse)
        t1 = [[None] * a for _ in range(a)]          # t1[i][k]
        for k in range(a):
            col = _lincomb(BT, [d[j * a + k] for j in range(a)])
            for i in range(a):
                t1[i][k] = col[i]
        V = [None] * R                               # V[i*a+l] = (tt, tc)
        for i in range(a):
            row = _lincomb(BT, t1[i])
            for l in range(a):
                V[i * a + l] = row[l]

        # per-position channel GEMMs, fp32-accumulated across C steps
        u = u_ref[...]                               # (R, tc, tm) f32
        part = jnp.stack([jnp.dot(V[r], u[r],
                                  preferred_element_type=jnp.float32)
                          for r in range(R)])        # (R, tt, tm)

        @pl.when(c == 0)
        def _init():
            acc_ref[...] = part

        @pl.when(c > 0)
        def _accumulate():
            acc_ref[...] += part

        @pl.when(c == pl.num_programs(2) - 1)
        def _finish():
            acc = acc_ref[...]
            mg = [[acc[i * a + l] for l in range(a)] for i in range(a)]
            # inverse transform A^T m A, then the fused epilogue
            t2 = [[None] * a for _ in range(m)]      # t2[u][l]
            for l in range(a):
                col = _lincomb(AT, [mg[i][l] for i in range(a)])
                for u_ in range(m):
                    t2[u_][l] = col[u_]
            ys = []
            for u_ in range(m):
                ys.extend(_lincomb(AT, t2[u_]))
            y = jnp.stack(ys)                        # (m*m, tt, tm)
            if has_bias:
                y = y + b_ref[...].astype(jnp.float32)[0]
            if has_add:
                y = y + ad_ref[...].astype(jnp.float32)
            if activation == "relu":
                y = jnp.maximum(y, 0.0)
            o_ref[...] = y.astype(o_ref.dtype)

    return kernel


def vmem_bytes(in_shape, filter_shape, m=2, tt=128, tm=128, tc=128,
               itemsize=4, bias=False, addend=False):
    """Live-block VMEM model of one grid step: input-tile and
    transformed-filter blocks double buffered, the f32 Winograd-domain
    accumulator, the output-tile block, plus the epilogue operands."""
    a = m + 2
    R = a * a
    need = (2 * (R * tt * tc * itemsize + R * tc * tm * 4)   # d, U blocks
            + R * tt * tm * 4                                # f32 domain acc
            + m * m * tt * tm * itemsize)                    # output tiles
    if bias:
        need += 2 * tm * 4
    if addend:
        need += 2 * m * m * tt * tm * itemsize
    return int(need)


@functools.partial(jax.jit, static_argnames=(
    "padding", "activation", "m", "tt", "tm", "tc", "interpret"))
def winograd_fused(x, w, padding=(1, 1), bias=None, activation=None,
                   addend=None, m=2, tt=128, tm=128, tc=128,
                   interpret=True):
    """x: (N, H, W, C) NHWC; w: (3, 3, C, M); stride-1 only.

    ``bias`` (M,), ``activation`` (None | 'relu') and ``addend``
    (residual second operand, output-shaped) are fused into the kernel
    epilogue — applied in VMEM after the inverse transform, before the
    single HBM write.  Returns (N, OH, OW, M) in ``x.dtype``.
    """
    N, H, W_, C = x.shape
    M = w.shape[3]
    ph, pw = padding
    OH, OW = H + 2 * ph - 2, W_ + 2 * pw - 2
    a = m + 2
    R = a * a
    th, tw = -(-OH // m), -(-OW // m)
    Hp, Wp = m * th + 2, m * tw + 2
    xp = jnp.pad(x, ((0, 0), (ph, Hp - H - ph), (pw, Wp - W_ - pw), (0, 0)))

    # overlapping a x a tiles with stride m, position-major (R, P, C)
    i_idx = (m * jnp.arange(th))[:, None] + jnp.arange(a)[None, :]
    j_idx = (m * jnp.arange(tw))[:, None] + jnp.arange(a)[None, :]
    tiles = xp[:, i_idx][:, :, :, j_idx]          # (N, th, a, tw, a, C)
    tiles = tiles.transpose(2, 4, 0, 1, 3, 5)     # (a, a, N, th, tw, C)
    P = N * th * tw
    d = tiles.reshape(R, P, C)
    U = transform_filters(w.astype(jnp.float32), m).reshape(R, C, M)

    (tt, tm, tc), (pp, pm, pc) = _compat.clamp_tiles((P, M, C),
                                                     (tt, tm, tc))
    d = jnp.pad(d, ((0, 0), (0, pp), (0, pc)))
    U = jnp.pad(U, ((0, 0), (0, pc), (0, pm)))
    grid = ((P + pp) // tt, (M + pm) // tm, (C + pc) // tc)

    has_bias = bias is not None
    has_add = addend is not None
    in_specs = [
        pl.BlockSpec((R, tt, tc), lambda p, mo, c: (0, p, c)),
        pl.BlockSpec((R, tc, tm), lambda p, mo, c: (0, c, mo)),
    ]
    operands = [d, U]
    if has_bias:
        in_specs.append(pl.BlockSpec((1, tm), lambda p, mo, c: (0, mo)))
        operands.append(jnp.pad(bias.reshape(1, M), ((0, 0), (0, pm))))
    if has_add:
        # gather the residual operand into the same output-tile layout
        ad = jnp.pad(addend, ((0, 0), (0, m * th - OH), (0, m * tw - OW),
                              (0, 0)))
        ad = ad.reshape(N, th, m, tw, m, M).transpose(2, 4, 0, 1, 3, 5)
        ad = jnp.pad(ad.reshape(m * m, P, M), ((0, 0), (0, pp), (0, pm)))
        in_specs.append(pl.BlockSpec((m * m, tt, tm),
                                     lambda p, mo, c: (0, p, mo)))
        operands.append(ad)
    out = pl.pallas_call(
        _make_kernel(m, has_bias, has_add, activation),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((m * m, tt, tm), lambda p, mo, c: (0, p, mo)),
        out_shape=jax.ShapeDtypeStruct((m * m, P + pp, M + pm), x.dtype),
        scratch_shapes=[pltpu.VMEM((R, tt, tm), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name=f"winograd_f{m}_fused",
    )(*operands)
    y = out[:, :P, :M].reshape(m, m, N, th, tw, M)
    y = y.transpose(2, 3, 0, 4, 1, 5).reshape(N, m * th, m * tw, M)
    return y[:, :OH, :OW, :]
