"""Fused cuConv: both stages in one kernel (beyond-paper optimization).

The paper's future-work section proposes "work-fusion".  On TPU the
Pallas grid-revisiting model makes it natural: the tap axis is the
innermost ("arbitrary") grid dimension, the output block's index_map
ignores it, so the output block stays resident in VMEM across all KH*KW
taps and the per-tap partials are accumulated *in registers/VMEM* instead
of round-tripping (KH*KW x output-size) temporaries through HBM.

Napkin math (7x7x832 in, 3x3 filter, M=384, f32 — paper table 4 "A"):
  two-stage HBM traffic: stage-1 write 9*49*384*4 = 677 KB/input
                       + stage-2 read  677 KB + write 75 KB
  fused:                 write 75 KB/input  (≈ 18x less output traffic)
Stage 1 dominates cuConv time in the paper (91-99 %); killing the
temporary stream attacks its memory term directly.

Grid: (N, OH, M_tiles, TAPS).  Per step: one padded input row
(1, 1, Wp, C) is selected by index_map *element* offset oh*sh + tap_dy
(legal because the H block dim is 1); the in-row X window for tap_dx at
stride sw is a dynamic_slice of length OW*sw reshaped to (OW, sw, C) and
column-sampled — a slice+reshape that stays TPU-legal (no gather); the
(OW x C) window hits the MXU against the (C x TM) tap matrix.

Epilogue (DESIGN.md §4): on the final tap the still-VMEM-resident
accumulator takes bias add + activation before the single HBM write —
``relu(conv(x, w) + b)`` costs no extra HBM round trip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import _compat


def _make_kernel(kw: int, ow: int, sw: int, taps: int, activation,
                 has_bias: bool):
    def _kernel(*refs):
        if has_bias:
            x_ref, w_ref, b_ref, o_ref = refs
        else:
            x_ref, w_ref, o_ref = refs
        t = pl.program_id(3)
        dj = jax.lax.rem(t, kw)
        row = x_ref[0, 0]                                   # (Wp', C)
        if sw == 1:
            win = jax.lax.dynamic_slice(
                row, (dj, 0), (ow, row.shape[1]))           # (OW, C)
        else:
            # strided window: contiguous (OW*sw, C) slice, column-sampled
            # via reshape — the padded input guarantees dj + OW*sw <= Wp'
            win = jax.lax.dynamic_slice(
                row, (dj, 0), (ow * sw, row.shape[1]))
            win = win.reshape(ow, sw, row.shape[1])[:, 0, :]
        part = jnp.dot(win, w_ref[0, 0],
                       preferred_element_type=jnp.float32)  # (OW, TM)

        @pl.when(t == 0)
        def _init():
            o_ref[0, 0] = part

        @pl.when(t > 0)
        def _acc():
            o_ref[0, 0] += part

        if has_bias or activation is not None:
            @pl.when(t == taps - 1)
            def _epilogue():
                acc = o_ref[0, 0]
                if has_bias:
                    acc = acc + b_ref[0].astype(jnp.float32)
                if activation == "relu":
                    acc = jnp.maximum(acc, 0.0)
                o_ref[0, 0] = acc

    return _kernel


@functools.partial(jax.jit, static_argnames=("stride", "padding",
                                             "activation", "tm", "interpret"))
def cuconv_fused(x, w, bias=None, stride=(1, 1), padding=(0, 0),
                 activation=None, tm=128, interpret=True):
    """x: (N, H, W, C) NHWC; w: (KH, KW, C, M) HWIO; stride (sh, sw) >= 1.

    bias: optional (M,) added on the final tap; activation: None | 'relu',
    applied after bias — both fused in VMEM before the output write.
    Returns (N, OH, OW, M) in x.dtype.
    """
    N, H, W, C = x.shape
    KH, KW, _, M = w.shape
    sh, sw = stride
    ph, pw = padding
    Hp, Wp = H + 2 * ph, W + 2 * pw
    OH, OW = (Hp - KH) // sh + 1, (Wp - KW) // sw + 1
    # widen rows so every tap's strided window slice stays in bounds:
    # max start KW-1 plus slice length OW*sw (== Wp when sw == 1)
    Wpad = KW - 1 + OW * sw
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw + max(0, Wpad - Wp)), (0, 0)))
    Wp = xp.shape[2]
    tm = min(tm, M)
    pm = (-M) % tm
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, pm)))
    has_bias = bias is not None
    grid = (N, OH, (M + pm) // tm, KH * KW)
    in_specs = [
        # one padded input row; H-dim block=1 => element-level shift
        pl.BlockSpec((1, 1, Wp, C),
                     lambda n, oh, m, t: (n, oh * sh + t // KW, 0, 0)),
        # the tap matrix F[di, dj] (C x TM), pinned in VMEM
        pl.BlockSpec((1, 1, C, tm),
                     lambda n, oh, m, t: (t // KW, jax.lax.rem(t, KW),
                                          0, m)),
    ]
    operands = [xp, wp]
    if has_bias:
        bp = jnp.pad(bias.reshape(1, M), ((0, 0), (0, pm)))
        in_specs.append(pl.BlockSpec((1, tm), lambda n, oh, m, t: (0, m)))
        operands.append(bp)
    out = pl.pallas_call(
        _make_kernel(KW, OW, sw, KH * KW, activation, has_bias),
        grid=grid,
        in_specs=in_specs,
        # output row revisited across all taps (index_map ignores t)
        out_specs=pl.BlockSpec((1, 1, OW, tm),
                               lambda n, oh, m, t: (n, oh, 0, m)),
        out_shape=jax.ShapeDtypeStruct((N, OH, OW, M + pm), jnp.float32),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="cuconv_fused",
    )(*operands)
    return out[..., :M].astype(x.dtype)


def vmem_bytes(x_shape, w_shape, tm=128, pad=(0, 0), stride=(1, 1),
               itemsize=4):
    """Static VMEM footprint estimate for the fused kernel's live blocks."""
    N, H, W, C = x_shape
    KH, KW, _, M = w_shape
    sh, sw = stride
    Wp = W + 2 * pad[1]
    OW = (Wp - KW) // sw + 1
    row = (KW - 1 + OW * sw) * C * itemsize
    wtap = C * min(tm, M) * itemsize
    out = OW * min(tm, M) * 4                # f32 accumulator
    return 2 * (row + wtap) + out            # x2: double buffering of inputs
