"""Fused cuConv: both stages in one kernel (beyond-paper optimization).

The paper's future-work section proposes "work-fusion".  On TPU the
Pallas grid-revisiting model makes it natural: the tap axis is the
innermost ("arbitrary") grid dimension, the output block's index_map
ignores it, so the output block stays resident in VMEM across all KH*KW
taps and the per-tap partials are accumulated *in registers/VMEM* instead
of round-tripping (KH*KW x output-size) temporaries through HBM.

Napkin math (7x7x832 in, 3x3 filter, M=384, f32 — paper table 4 "A"):
  two-stage HBM traffic: stage-1 write 9*49*384*4 = 677 KB/input
                       + stage-2 read  677 KB + write 75 KB
  fused:                 write 75 KB/input  (≈ 18x less output traffic)
Stage 1 dominates cuConv time in the paper (91-99 %); killing the
temporary stream attacks its memory term directly.

Launch configuration (DESIGN.md §9): the kernel geometry is *tunable* —
``tm`` is the output-channel tile, ``rows`` the number of output rows
each grid step produces.

``rows=1`` (the historical geometry) — grid (N, OH, M_tiles, TAPS).
Per step: one padded input row (1, 1, Wp, C) is selected by index_map
*element* offset oh*sh + tap_dy (legal because the H block dim is 1);
the in-row X window for tap_dx at stride sw is a dynamic_slice of
length OW*sw reshaped to (OW, sw, C) and column-sampled — a
slice+reshape that stays TPU-legal (no gather); the (OW x C) window
hits the MXU against the (C x TM) tap matrix.

``rows>=2`` (multi-row output blocking) — grid (N, ceil(OH/rows),
M_tiles, TAPS).  The short-``OW`` paper configs (7x7, 13x13) only fill
a handful of MXU sublanes with a single output row; multi-row blocking
feeds a (rows*OW x C) window per step instead.  Element-offset
index_maps need a block dim of 1, so the halo is covered differently
here: TWO adjacent aligned H-blocks of ``rows*sh`` input rows each are
staged per step, concatenated in VMEM, and the tap's (rows, OW) window
is carved out with one dynamic_slice + reshape (strided row/column
sampling, no gather).  Validity: KH - 1 <= rows*sh, so every tap's
window lands inside the two staged blocks — ``config_supports`` on the
executor prunes the rest.

Epilogue (DESIGN.md §4): on the final tap the still-VMEM-resident
accumulator takes bias add + activation before the single HBM write —
``relu(conv(x, w) + b)`` costs no extra HBM round trip.

Cross-layer fusion (DESIGN.md §10) extends the same epilogue slot:

``addend`` — a residual second operand (shape == the conv output) whose
block rides the output's index_map, added after the bias and before the
activation, so a ResNet shortcut join (``relu(conv(x) + b + shortcut)``)
also costs no extra HBM round trip.

``pool`` — a trailing non-overlapping max/avg pool ``(kind, psh, psw)``
(window == stride, no padding) folded into the multi-row path: the conv
partials accumulate in an f32 VMEM *scratch* block of ``rows`` output
rows; on the final tap the epilogue runs and the block is pooled with
static strided slices (no gather) down to ``(rows/psh, OW/psw)`` before
the single — now pool-sized — HBM write.  Validity (``config_supports``
on the executor enforces it): ``rows % psh == 0``, ``OH % rows == 0``,
``OW % psw == 0`` and the multi-row halo rule ``KH - 1 <= rows*sh``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat


def _make_kernel(kw: int, ow: int, sw: int, taps: int, activation,
                 has_bias: bool, has_add: bool = False):
    def _kernel(*refs):
        refs = list(refs)
        x_ref, w_ref = refs.pop(0), refs.pop(0)
        b_ref = refs.pop(0) if has_bias else None
        a_ref = refs.pop(0) if has_add else None
        o_ref = refs.pop(0)
        t = pl.program_id(3)
        dj = jax.lax.rem(t, kw)
        row = x_ref[0, 0]                                   # (Wp', C)
        if sw == 1:
            win = jax.lax.dynamic_slice(
                row, (dj, 0), (ow, row.shape[1]))           # (OW, C)
        else:
            # strided window: contiguous (OW*sw, C) slice, column-sampled
            # via reshape — the padded input guarantees dj + OW*sw <= Wp'
            win = jax.lax.dynamic_slice(
                row, (dj, 0), (ow * sw, row.shape[1]))
            win = win.reshape(ow, sw, row.shape[1])[:, 0, :]
        part = jnp.dot(win, w_ref[0, 0],
                       preferred_element_type=jnp.float32)  # (OW, TM)

        @pl.when(t == 0)
        def _init():
            o_ref[0, 0] = part

        @pl.when(t > 0)
        def _acc():
            o_ref[0, 0] += part

        if has_bias or has_add or activation is not None:
            @pl.when(t == taps - 1)
            def _epilogue():
                acc = o_ref[0, 0]
                if has_bias:
                    acc = acc + b_ref[0].astype(jnp.float32)
                if has_add:
                    acc = acc + a_ref[0, 0].astype(jnp.float32)
                if activation == "relu":
                    acc = jnp.maximum(acc, 0.0)
                o_ref[0, 0] = acc

    return _kernel


def _pool_block(acc, kind: str, psh: int, psw: int):
    """Non-overlapping (window == stride) pool of a (rows, OW, TM) VMEM
    block via static strided slices — no gather, TPU-legal."""
    pooled = None
    for i in range(psh):
        for j in range(psw):
            piece = acc[i::psh, j::psw, :]
            if pooled is None:
                pooled = piece
            elif kind == "max":
                pooled = jnp.maximum(pooled, piece)
            else:
                pooled = pooled + piece
    if kind == "avg":
        pooled = pooled / (psh * psw)
    return pooled


def _make_multirow_kernel(kw: int, ow: int, sh: int, sw: int, rows: int,
                          taps: int, activation, has_bias: bool,
                          has_add: bool = False, pool=None):
    def _kernel(*refs):
        refs = list(refs)
        xa_ref, xb_ref, w_ref = refs.pop(0), refs.pop(0), refs.pop(0)
        b_ref = refs.pop(0) if has_bias else None
        a_ref = refs.pop(0) if has_add else None
        o_ref = refs.pop(0)
        acc_ref = refs.pop(0) if pool is not None else None
        t = pl.program_id(3)
        di = t // kw
        dj = jax.lax.rem(t, kw)
        # two adjacent aligned H blocks of rows*sh input rows each; the
        # tap's window starts at local offset di (<= rows*sh by the
        # KH - 1 <= rows*sh validity rule), so it always fits the pair
        big = jnp.concatenate([xa_ref[0], xb_ref[0]], axis=0)
        C = big.shape[-1]
        blk = jax.lax.dynamic_slice(
            big, (di, dj, 0), (rows * sh, ow * sw, C))
        if sh > 1:
            blk = blk.reshape(rows, sh, ow * sw, C)[:, 0]   # (rows, OW*sw, C)
        if sw > 1:
            blk = blk.reshape(rows, ow, sw, C)[:, :, 0, :]  # (rows, OW, C)
        win = blk.reshape(rows * ow, C)
        part = jnp.dot(win, w_ref[0, 0],
                       preferred_element_type=jnp.float32)  # (rows*OW, TM)
        part = part.reshape(rows, ow, part.shape[-1])

        if pool is not None:
            # conv partials accumulate in the f32 VMEM scratch; the
            # output block only ever sees the pooled final tap
            kind, psh, psw = pool

            @pl.when(t == 0)
            def _init():
                acc_ref[...] = part

            @pl.when(t > 0)
            def _acc():
                acc_ref[...] += part

            @pl.when(t == taps - 1)
            def _epilogue():
                acc = acc_ref[...]
                if has_bias:
                    acc = acc + b_ref[0].astype(jnp.float32)
                if activation == "relu":
                    acc = jnp.maximum(acc, 0.0)
                o_ref[0] = _pool_block(acc, kind, psh, psw)

            return

        @pl.when(t == 0)
        def _init():
            o_ref[0] = part

        @pl.when(t > 0)
        def _acc():
            o_ref[0] += part

        if has_bias or has_add or activation is not None:
            @pl.when(t == taps - 1)
            def _epilogue():
                acc = o_ref[0]
                if has_bias:
                    acc = acc + b_ref[0].astype(jnp.float32)
                if has_add:
                    acc = acc + a_ref[0].astype(jnp.float32)
                if activation == "relu":
                    acc = jnp.maximum(acc, 0.0)
                o_ref[0] = acc

    return _kernel


@functools.partial(jax.jit, static_argnames=("stride", "padding",
                                             "activation", "pool",
                                             "tm", "rows", "interpret"))
def cuconv_fused(x, w, bias=None, stride=(1, 1), padding=(0, 0),
                 activation=None, addend=None, pool=None,
                 tm=128, rows=1, interpret=True):
    """x: (N, H, W, C) NHWC; w: (KH, KW, C, M) HWIO; stride (sh, sw) >= 1.

    bias: optional (M,) added on the final tap; activation: None | 'relu',
    applied after bias — both fused in VMEM before the output write.
    addend: optional (N, OH, OW, M) residual operand added after the
    bias and before the activation (cross-layer add fusion).  pool:
    optional ``(kind, psh, psw)`` non-overlapping max/avg pool (window
    == stride, no padding) applied to the finished block in VMEM before
    writeback; mutually exclusive with ``addend``.
    ``tm``/``rows`` are the launch configuration (output-channel tile and
    output rows per grid step); ``rows >= 2`` requires
    ``KH - 1 <= rows*sh`` (the multi-row halo rule — the planner's
    ``config_supports`` prunes invalid candidates).  ``pool`` always
    takes the multi-row path and additionally needs ``rows % psh == 0``,
    ``OH % rows == 0`` and ``OW % psw == 0``.
    Returns (N, OH, OW, M) — pooled to (N, OH/psh, OW/psw, M) under
    ``pool`` — in x.dtype.
    """
    N, H, W, C = x.shape
    KH, KW, _, M = w.shape
    sh, sw = stride
    ph, pw = padding
    Hp, Wp = H + 2 * ph, W + 2 * pw
    OH, OW = (Hp - KH) // sh + 1, (Wp - KW) // sw + 1
    rows = min(int(rows), OH)
    if rows < 1:
        raise ValueError(f"rows must be >= 1; got {rows}")
    if (rows > 1 or pool is not None) and KH - 1 > rows * sh:
        raise ValueError(
            f"multi-row blocking needs KH - 1 <= rows*sh to cover the tap "
            f"halo from two aligned input blocks; got KH={KH}, rows={rows}, "
            f"sh={sh}")
    if pool is not None:
        if addend is not None:
            raise ValueError("pool and addend fusions are mutually "
                             "exclusive (ConvSpec enforces this)")
        kind, psh, psw = pool
        if kind not in ("max", "avg"):
            raise ValueError(f"pool kind must be 'max' or 'avg'; "
                             f"got {pool!r}")
        if rows % psh or OH % rows or OW % psw:
            raise ValueError(
                f"fused pool needs rows % psh == 0, OH % rows == 0 and "
                f"OW % psw == 0; got rows={rows}, OH={OH}, OW={OW}, "
                f"pool={pool!r}")
    if addend is not None and addend.shape != (N, OH, OW, M):
        raise ValueError(f"addend shape {addend.shape} != conv output "
                         f"shape {(N, OH, OW, M)}")
    # widen rows so every tap's strided window slice stays in bounds:
    # max start KW-1 plus slice length OW*sw (== Wp when sw == 1)
    Wpad = KW - 1 + OW * sw
    (tm,), (pm,) = _compat.clamp_tiles((M,), (tm,))
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, pm)))
    has_bias = bias is not None
    has_add = addend is not None
    kw_common = dict(
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="cuconv_fused",
    )

    if rows == 1 and pool is None:
        xp = jnp.pad(x, ((0, 0), (ph, ph),
                         (pw, pw + max(0, Wpad - Wp)), (0, 0)))
        Wp = xp.shape[2]
        grid = (N, OH, (M + pm) // tm, KH * KW)
        in_specs = [
            # one padded input row; H-dim block=1 => element-level shift
            pl.BlockSpec((1, 1, Wp, C),
                         lambda n, oh, m, t: (n, oh * sh + t // KW, 0, 0)),
            # the tap matrix F[di, dj] (C x TM), pinned in VMEM
            pl.BlockSpec((1, 1, C, tm),
                         lambda n, oh, m, t: (t // KW, jax.lax.rem(t, KW),
                                              0, m)),
        ]
        operands = [xp, wp]
        if has_bias:
            bp = jnp.pad(bias.reshape(1, M), ((0, 0), (0, pm)))
            in_specs.append(pl.BlockSpec((1, tm),
                                         lambda n, oh, m, t: (0, m)))
            operands.append(bp)
        if has_add:
            # the residual block rides the output's index_map
            ap = jnp.pad(addend, ((0, 0), (0, 0), (0, 0), (0, pm)))
            in_specs.append(pl.BlockSpec((1, 1, OW, tm),
                                         lambda n, oh, m, t: (n, oh, 0, m)))
            operands.append(ap)
        out = pl.pallas_call(
            _make_kernel(KW, OW, sw, KH * KW, activation, has_bias,
                         has_add),
            grid=grid,
            in_specs=in_specs,
            # output row revisited across all taps (index_map ignores t)
            out_specs=pl.BlockSpec((1, 1, OW, tm),
                                   lambda n, oh, m, t: (n, oh, 0, m)),
            out_shape=jax.ShapeDtypeStruct((N, OH, OW, M + pm), jnp.float32),
            **kw_common,
        )(*operands)
        return out[..., :M].astype(x.dtype)

    # multi-row blocking: rows output rows per step from two adjacent
    # aligned input blocks of B = rows*sh rows each
    B = rows * sh
    OHB = -(-OH // rows)
    # H must cover block index OHB (the second staged block of the last
    # step) => (OHB + 1) * B padded rows; extra rows are zeros and the
    # outputs they feed are sliced away below
    hpad_extra = max(0, (OHB + 1) * B - Hp)
    xp = jnp.pad(x, ((0, 0), (ph, ph + hpad_extra),
                     (pw, pw + max(0, Wpad - Wp)), (0, 0)))
    Wp = xp.shape[2]
    grid = (N, OHB, (M + pm) // tm, KH * KW)
    in_specs = [
        pl.BlockSpec((1, B, Wp, C), lambda n, oh, m, t: (n, oh, 0, 0)),
        pl.BlockSpec((1, B, Wp, C), lambda n, oh, m, t: (n, oh + 1, 0, 0)),
        pl.BlockSpec((1, 1, C, tm),
                     lambda n, oh, m, t: (t // KW, jax.lax.rem(t, KW),
                                          0, m)),
    ]
    operands = [xp, xp, wp]
    if has_bias:
        bp = jnp.pad(bias.reshape(1, M), ((0, 0), (0, pm)))
        in_specs.append(pl.BlockSpec((1, tm), lambda n, oh, m, t: (0, m)))
        operands.append(bp)
    if has_add:
        # OH padded up to the block grid so the last step's residual
        # block exists; the padded rows feed outputs sliced away below
        ap = jnp.pad(addend, ((0, 0), (0, OHB * rows - OH), (0, 0),
                              (0, pm)))
        in_specs.append(pl.BlockSpec((1, rows, OW, tm),
                                     lambda n, oh, m, t: (n, oh, 0, m)))
        operands.append(ap)
    if pool is not None:
        kind, psh, psw = pool
        out = pl.pallas_call(
            _make_multirow_kernel(KW, OW, sh, sw, rows, KH * KW, activation,
                                  has_bias, has_add, pool=(kind, psh, psw)),
            grid=grid,
            in_specs=in_specs,
            # the output block is the POOLED tile: rows/psh rows per step
            out_specs=pl.BlockSpec((1, rows // psh, OW // psw, tm),
                                   lambda n, oh, m, t: (n, oh, 0, m)),
            out_shape=jax.ShapeDtypeStruct(
                (N, (OHB * rows) // psh, OW // psw, M + pm), jnp.float32),
            # conv partials accumulate here, not in the output block
            scratch_shapes=[pltpu.VMEM((rows, OW, tm), jnp.float32)],
            **kw_common,
        )(*operands)
        return out[:, :OH // psh, :, :M].astype(x.dtype)
    out = pl.pallas_call(
        _make_multirow_kernel(KW, OW, sh, sw, rows, KH * KW, activation,
                              has_bias, has_add),
        grid=grid,
        in_specs=in_specs,
        # (rows, OW, TM) output block revisited across all taps
        out_specs=pl.BlockSpec((1, rows, OW, tm),
                               lambda n, oh, m, t: (n, oh, 0, m)),
        out_shape=jax.ShapeDtypeStruct((N, OHB * rows, OW, M + pm),
                                       jnp.float32),
        **kw_common,
    )(*operands)
    return out[:, :OH, :, :M].astype(x.dtype)


def vmem_bytes(x_shape, w_shape, tm=128, rows=1, pad=(0, 0), stride=(1, 1),
               itemsize=4, addend=False, pool=None):
    """Static VMEM footprint estimate for the fused kernel's live blocks
    under launch config ``(tm, rows)``.

    ``addend`` adds the residual input block (it rides the output
    index_map, double buffered like any input); ``pool`` —
    ``(kind, psh, psw)`` — adds the f32 scratch accumulator next to the
    (smaller) pooled output block.
    """
    N, H, W, C = x_shape
    KH, KW, _, M = w_shape
    sh, sw = stride
    Wp = W + 2 * pad[1]
    OW = (Wp - KW) // sw + 1
    OH = (H + 2 * pad[0] - KH) // sh + 1
    rows = max(1, min(int(rows), OH))
    tm = min(int(tm), M)
    wtap = C * tm * itemsize
    out = rows * OW * tm * 4                     # f32 accumulator
    if pool is not None:
        _, psh, psw = pool
        # scratch accumulator + the pooled output block
        out = rows * OW * tm * 4 \
            + (rows // max(1, psh)) * (OW // max(1, psw)) * tm * 4
    add_blk = 2 * rows * OW * tm * itemsize if addend else 0
    row_bytes = (KW - 1 + OW * sw) * C * itemsize
    if rows == 1 and pool is None:
        return 2 * (row_bytes + wtap) + out + add_blk  # x2: double buffering
    blk = rows * sh * row_bytes                  # one aligned H block
    return 2 * (2 * blk + wtap) + out + add_blk  # two staged blocks per step
