"""Fused cuConv: both stages in one kernel (beyond-paper optimization).

The paper's future-work section proposes "work-fusion".  On TPU the
Pallas grid-revisiting model makes it natural: the tap axis is the
innermost ("arbitrary") grid dimension, the output block's index_map
ignores it, so the output block stays resident in VMEM across all KH*KW
taps and the per-tap partials are accumulated *in registers/VMEM* instead
of round-tripping (KH*KW x output-size) temporaries through HBM.

Napkin math (7x7x832 in, 3x3 filter, M=384, f32 — paper table 4 "A"):
  two-stage HBM traffic: stage-1 write 9*49*384*4 = 677 KB/input
                       + stage-2 read  677 KB + write 75 KB
  fused:                 write 75 KB/input  (≈ 18x less output traffic)
Stage 1 dominates cuConv time in the paper (91-99 %); killing the
temporary stream attacks its memory term directly.

Grid: (N, OH, M_tiles, TAPS).  Per step: one padded input row
(1, 1, Wp, C) is selected by index_map *element* offset oh + tap_dy
(legal because the H block dim is 1); the in-row X shift tap_dx is a
dynamic_slice in VMEM; the (OW x C) window hits the MXU against the
(C x TM) tap matrix.  Stride 1 (the paper's entire evaluation set).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _make_kernel(kw: int, ow: int):
    def _kernel(x_ref, w_ref, o_ref):
        t = pl.program_id(3)
        dj = jax.lax.rem(t, kw)
        row = x_ref[0, 0]                                   # (Wp, C)
        win = jax.lax.dynamic_slice(
            row, (dj, 0), (ow, row.shape[1]))               # (OW, C)
        part = jnp.dot(win, w_ref[0, 0],
                       preferred_element_type=jnp.float32)  # (OW, TM)

        @pl.when(t == 0)
        def _init():
            o_ref[0, 0] = part

        @pl.when(t > 0)
        def _acc():
            o_ref[0, 0] += part

    return _kernel


@functools.partial(jax.jit, static_argnames=("padding", "tm", "interpret"))
def cuconv_fused(x, w, padding=(0, 0), tm=128, interpret=True):
    """x: (N, H, W, C) NHWC; w: (KH, KW, C, M) HWIO; stride 1.

    Returns (N, OH, OW, M) in x.dtype.
    """
    N, H, W, C = x.shape
    KH, KW, _, M = w.shape
    ph, pw = padding
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    Hp, Wp = H + 2 * ph, W + 2 * pw
    OH, OW = Hp - KH + 1, Wp - KW + 1
    tm = min(tm, M)
    pm = (-M) % tm
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, pm)))
    grid = (N, OH, (M + pm) // tm, KH * KW)
    out = pl.pallas_call(
        _make_kernel(KW, OW),
        grid=grid,
        in_specs=[
            # one padded input row; H-dim block=1 => element-level shift
            pl.BlockSpec((1, 1, Wp, C),
                         lambda n, oh, m, t: (n, oh + t // KW, 0, 0)),
            # the tap matrix F[di, dj] (C x TM), pinned in VMEM
            pl.BlockSpec((1, 1, C, tm),
                         lambda n, oh, m, t: (t // KW, jax.lax.rem(t, KW),
                                              0, m)),
        ],
        # output row revisited across all taps (index_map ignores t)
        out_specs=pl.BlockSpec((1, 1, OW, tm),
                               lambda n, oh, m, t: (n, oh, 0, m)),
        out_shape=jax.ShapeDtypeStruct((N, OH, OW, M + pm), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="cuconv_fused",
    )(xp, wp)
    return out[..., :M].astype(x.dtype)


def vmem_bytes(x_shape, w_shape, tm=128, pad=(0, 0)):
    """Static VMEM footprint estimate for the fused kernel's live blocks."""
    N, H, W, C = x_shape
    KH, KW, _, M = w_shape
    Wp = W + 2 * pad[1]
    OW = Wp - KW + 1
    row = Wp * C * 4
    wtap = C * min(tm, M) * 4
    out = OW * min(tm, M) * 4
    return 2 * (row + wtap) + out        # x2: double buffering of inputs
