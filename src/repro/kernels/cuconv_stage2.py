"""cuConv stage 2 (faithful): sum the KH*KW per-tap partial matrices.

The CUDA `sum_kernel` gathers one element from each of the KH*KW
temporary matrices per output element.  TPU mapping: the tap axis is the
*sublane-major* axis of a (T, tile_p, tile_m) VMEM block, reduced with a
single vector-add tree per block — purely bandwidth-bound, exactly like
the original (paper tables 4/5 show stage 2 at 1-9% of total time).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import _compat


def _kernel(t_ref, o_ref):
    o_ref[...] = jnp.sum(t_ref[...].astype(jnp.float32), axis=0).astype(
        o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tp", "tm", "out_dtype",
                                             "interpret"))
def stage2_tap_sum(temps, tp=256, tm=256, out_dtype=jnp.float32,
                   interpret=True):
    """temps: (T, P, M) stage-1 partials -> (P, M) output plane sums."""
    T, P, M = temps.shape
    (tp, tm), (pp, pm) = _compat.clamp_tiles((P, M), (tp, tm))
    tpad = jnp.pad(temps, ((0, 0), (0, pp), (0, pm)))
    grid = ((P + pp) // tp, (M + pm) // tm)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((T, tp, tm), lambda p, m: (0, p, m))],
        out_specs=pl.BlockSpec((tp, tm), lambda p, m: (p, m)),
        out_shape=jax.ShapeDtypeStruct((P + pp, M + pm), out_dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
        name="cuconv_stage2",
    )(tpad)
    return out[:P, :M]
