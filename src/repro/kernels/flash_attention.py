"""Blockwise (flash) attention forward kernel.

Not a paper contribution — it is the perf-critical layer of the LM
substrate the framework serves/trains.  Online-softmax recurrence over
KV tiles; the KV grid dim is innermost/arbitrary so the accumulator,
running max m and denominator l stay VMEM-resident per query tile.

Scratch uses (tq, 1)-shaped m/l for clarity; a production TPU build
would lane-replicate to (tq, 128) to avoid sublane relayouts.  Causal
query tiles entirely below the diagonal skip compute via pl.when.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import _compat

NEG_INF = -1e30


def _make_kernel(tq: int, tk: int, sk_real: int, causal: bool):
    def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        iq, ik = pl.program_id(1), pl.program_id(2)
        qo = iq * tq
        ko = ik * tk

        @pl.when(ik == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        # skip KV tiles strictly above the causal diagonal
        run = (ko <= qo + tq - 1) if causal else True

        @pl.when(run)
        def _step():
            q = q_ref[0]                                    # (tq, D)
            k = k_ref[0]                                    # (tk, D)
            v = v_ref[0]
            s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
            s *= 1.0 / (q.shape[-1] ** 0.5)
            kv_idx = ko + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
            valid = kv_idx < sk_real
            if causal:
                q_idx = qo + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
                valid = valid & (q_idx >= kv_idx)
            s = jnp.where(valid, s, NEG_INF)
            m_prev = m_ref[...]                             # (tq, 1)
            m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            p = jnp.where(valid, p, 0.0)
            corr = jnp.exp(m_prev - m_new)
            l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
            m_ref[...] = m_new
            acc_ref[...] = acc_ref[...] * corr + jnp.dot(
                p.astype(v.dtype), v, preferred_element_type=jnp.float32)

        @pl.when(ik == pl.num_programs(2) - 1)
        def _done():
            l = jnp.maximum(l_ref[...], 1e-30)
            o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)

    return _kernel


@functools.partial(jax.jit,
                   static_argnames=("causal", "tq", "tk", "interpret"))
def flash_attention(q, k, v, causal=True, tq=256, tk=256, interpret=True):
    """q: (BH, Sq, D); k, v: (BH, Sk, D).  Softmax(QK^T/sqrt(D))V."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    tq, tk = min(tq, Sq), min(tk, Sk)
    pq, pk = (-Sq) % tq, (-Sk) % tk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    grid = (BH, (Sq + pq) // tq, (Sk + pk) // tk)
    out = pl.pallas_call(
        _make_kernel(tq, tk, Sk, causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, tk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq + pq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, D), jnp.float32),
        ],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="flash_attention",
    )(qp, kp, vp)
    return out[:, :Sq, :]
