"""Int8 x int8 -> int32 tiled GEMM — the quantized inference fast path.

Same structure as ``conv1x1.py``'s pixels-major GEMM (all three dims
tiled to VMEM blocks, contraction grid dim innermost, accumulator in
VMEM scratch across C-revisits), but the operands are int8 and the
accumulator is **int32**: ``preferred_element_type=jnp.int32`` drives
the MXU's integer path, which is the "roughly double arithmetic
throughput" lever the ROADMAP names — int8 tiles are a quarter the
bytes of f32, so the same VMEM budget holds 4x the tile footprint and
the MXU runs its 8-bit mode.

The kernel returns the raw int32 accumulator; dequantization
(``acc * (x_scale * w_scale[m])``) and the fp32 epilogue are the
*executor's* job (DESIGN.md §13: requantization order), so one kernel
serves every scale layout.

Min int8 tile on TPU is (32, 128) (sublane x lane); the default blocks
are 128-multiples well above that floor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import _compat


def _kernel(x_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("tp", "tm", "tc", "interpret"))
def int8_gemm(x2d, w, tp=256, tm=128, tc=512, interpret=True):
    """x2d: (P, C) int8 pixels-major; w: (C, M) int8.

    Returns (P, M) **int32** — the undequantized accumulator.  Zero
    padding is exact under symmetric quantization (0 maps to code 0),
    so padded rows/columns contribute nothing to real outputs.
    """
    P, C = x2d.shape
    _, M = w.shape
    (tp, tm, tc), (pp, pm, pc) = _compat.clamp_tiles((P, M, C),
                                                     (tp, tm, tc))
    xp = jnp.pad(x2d, ((0, pp), (0, pc)))
    wp = jnp.pad(w, ((0, pc), (0, pm)))
    grid = ((P + pp) // tp, (M + pm) // tm, (C + pc) // tc)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tp, tc), lambda p, m, c: (p, c)),
            pl.BlockSpec((tc, tm), lambda p, m, c: (c, m)),
        ],
        out_specs=pl.BlockSpec((tp, tm), lambda p, m, c: (p, m)),
        out_shape=jax.ShapeDtypeStruct((P + pp, M + pm), jnp.int32),
        scratch_shapes=[pltpu.VMEM((tp, tm), jnp.int32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="int8_gemm",
    )(xp, wp)
    return out[:P, :M]
