from repro.roofline.analysis import analyze_all, HW  # noqa: F401
