"""Three-term roofline from the dry-run artifacts (TPU v5e constants).

  compute term    = HLO_FLOPs / (peak bf16 FLOP/s)         [per chip]
  memory term     = HLO_bytes / HBM bandwidth              [per chip]
  collective term = collective_bytes / ICI link bandwidth  [per chip]

HLO_FLOPs / bytes / collective bytes are the probe-extrapolated totals
(see launch/dryrun.py: XLA cost analysis counts while bodies once, so
unrolled 1/2-period probes are extrapolated linearly — exact).
MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens (inference)
— the "useful" compute; its ratio to HLO flops exposes remat/redundancy.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "TPU v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # B/s per chip
    ici_bw: float = 50e9                # B/s per link


HW = Hardware()


def analyze_record(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "OK":
        return None
    probe = rec.get("probe", {})
    flops = probe.get("flops_total_per_device")
    byts = probe.get("bytes_total_per_device")
    coll = probe.get("collective_bytes_total_per_device")
    if flops is None:
        flops = rec.get("flops_per_device")
        byts = rec.get("bytes_accessed_per_device")
        coll = rec.get("collective_bytes_per_device")
    t_c = flops / HW.peak_flops
    t_m = byts / HW.hbm_bw
    t_x = coll / HW.ici_bw
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    mult = 6 if rec["kind"] == "train" else 2
    model_flops = mult * rec["active_params"] * rec["tokens"]
    hlo_global = flops * rec["devices"]
    bound_time = max(terms.values())
    # roofline fraction: useful model flops over the time the dominant
    # term pins the step at, vs the chip's peak
    frac = (model_flops / rec["devices"] / bound_time) / HW.peak_flops
    levers = {
        "compute": ("reduce recompute (remat policy) or cast accumulations "
                    "to bf16 where safe"),
        "memory": ("fuse/eliminate f32 round-trips (chunked CE loss, bf16 "
                   "intermediates) and shrink materialized buffers"),
        "collective": ("swap all-reduce for reduce-scatter+all-gather "
                       "(sequence-sharded residuals) and bf16 collectives"),
    }
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom, "model_flops": model_flops,
        "hlo_flops_global": hlo_global,
        "useful_ratio": model_flops / hlo_global if hlo_global else 0.0,
        "roofline_frac": frac,
        "peak_gib": rec["memory"]["peak_bytes"] / 2**30,
        "lever": levers[dom],
    }


def analyze_all(art_dir="artifacts/dryrun") -> List[Dict]:
    out = []
    for f in sorted(Path(art_dir).glob("*.json")):
        rec = json.loads(f.read_text())
        row = analyze_record(rec)
        if row is None:
            row = {"arch": rec["arch"], "shape": rec["shape"],
                   "mesh": rec["mesh"], "status": rec["status"]}
        else:
            row["status"] = "OK"
        row["variant"] = rec.get("variant", "")
        out.append(row)
    return out


def to_markdown(rows: List[Dict], mesh: str = "16x16") -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | bound | "
           "6ND/HLO | roofline frac | peak GiB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r.get("mesh") != mesh or r.get("variant"):
            continue
        if r["status"] != "OK":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['status']} | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.3f} | {r['peak_gib']:.1f} |")
    return hdr + "\n".join(lines) + "\n"


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = analyze_all(args.art)
    print(to_markdown(rows, args.mesh))


if __name__ == "__main__":
    main()
