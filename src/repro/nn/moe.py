"""Mixture-of-experts MLP (DeepSeek-style: shared + fine-grained routed).

Dispatch is grouped gather/scatter: tokens are routed *within groups*
(one group per data shard, so routing never crosses the batch sharding),
and each expert gathers its top-capacity tokens by gate value
(expert-choice capacity).  This avoids the O(T x E x C) one-hot dispatch
tensor of the classic GShard einsum — at 1M tokens that tensor is
~3e13 elements, which is why the first implementation was replaced
(see DESIGN.md §MoE) — while still lowering to dense gathers/matmuls
that the SPMD partitioner shards cleanly (experts over 'model', groups
over 'data').
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import layers as L


def moe_init(key, cfg):
    ks = jax.random.split(key, 5)
    E, D, F = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    scale = 1.0 / jnp.sqrt(D)

    def expert_bank(k):
        k1, k2, k3 = jax.random.split(k, 3)
        mk = lambda kk, a, b: (jax.random.normal(kk, (E, a, b), jnp.float32)
                               * (1.0 / jnp.sqrt(a))).astype(L.DEFAULT_DTYPE)
        return {"wi": mk(k1, D, F), "wg": mk(k2, D, F), "wo": mk(k3, F, D)}

    p = {"router": {"w": (jax.random.normal(ks[0], (D, E), jnp.float32)
                          * scale).astype(jnp.float32)},
         "experts": expert_bank(ks[1])}
    if cfg.num_shared_experts:
        p["shared"] = L.mlp_init(ks[2], D, F * cfg.num_shared_experts)
    return p


def moe_fwd(p, cfg, x, dropless=False, n_groups=1):
    """x: (B, S, D) -> (B, S, D), plus aux metrics dict.

    n_groups: routing groups (set to the data-parallel degree so groups
    align with batch shards).  dropless=True sets per-expert capacity to
    the whole group (exact; used for decode where T is tiny).
    """
    B, S, D = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.experts_per_token
    if T % n_groups != 0:
        n_groups = 1
    G = T // n_groups
    xg = x.reshape(n_groups, G, D)

    logits = jnp.einsum("ngd,de->nge", xg.astype(jnp.float32),
                        p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)                     # (ng,G,E)
    gates, eidx = jax.lax.top_k(probs, K)                       # (ng,G,K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # dense (group, token, expert) gate matrix; non-routed entries are 0
    onehot = jax.nn.one_hot(eidx, E, dtype=jnp.float32)         # (ng,G,K,E)
    gate_te = jnp.einsum("ngke,ngk->nge", onehot, gates)        # (ng,G,E)

    if dropless:
        C = G
    else:
        C = max(1, int(cfg.capacity_factor * G * K / E))
        C = min(C, G)

    # expert-choice capacity: each expert takes its top-C tokens by gate
    vals, tok_idx = jax.lax.top_k(gate_te.transpose(0, 2, 1), C)  # (ng,E,C)

    def group_fn(xg_g, tok_idx_g, vals_g):
        ein = jnp.take_along_axis(
            xg_g[None, :, :], tok_idx_g[:, :, None], axis=1)      # (E,C,D)
        ex = p["experts"]
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ein, ex["wi"]))
        h = h * jnp.einsum("ecd,edf->ecf", ein, ex["wg"])
        eout = jnp.einsum("ecf,efd->ecd", h, ex["wo"])            # (E,C,D)
        w = eout.astype(jnp.float32) * vals_g[:, :, None]
        out = jnp.zeros((G, D), jnp.float32)
        out = out.at[tok_idx_g.reshape(-1)].add(w.reshape(-1, D))
        return out

    out = jax.vmap(group_fn)(xg, tok_idx, vals).astype(x.dtype)
    out = out.reshape(B, S, D)

    if cfg.num_shared_experts:
        out = out + L.mlp_fwd(p["shared"], x)

    # load-balance aux loss (Switch-style) + dropped-token fraction
    me = probs.mean((0, 1))                                      # (E,)
    ce = onehot.sum(2).mean((0, 1))                              # (E,)
    kept = (vals > 0).sum(axis=(1, 2)).astype(jnp.float32)       # per group
    routed = (gate_te > 0).sum(axis=(1, 2)).astype(jnp.float32)
    aux = {"load_balance_loss": E * jnp.sum(me * ce),
           "dropped_frac": 1.0 - (kept / jnp.maximum(routed, 1.0)).mean()}
    return out, aux
