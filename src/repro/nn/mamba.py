"""Mamba2 block (state-space duality / SSD), chunked, pure JAX.

Follows the minimal SSD formulation of Dao & Gu 2024 (arXiv:2405.21060):
within chunks the recurrence is computed as masked matmuls (the "dual"
quadratic form, MXU-friendly); across chunks a linear scan carries the
(heads, head_dim, state) SSM state.

TP note: the input projections are stored as *separate* z/x/B/C/dt
matrices (not one fused in_proj) and the depthwise conv as per-stream
weights, so every tensor-parallel shard boundary falls on a whole
logical stream — no resharding collectives inside the block.  The
depthwise causal conv1d uses the cuConv tap decomposition
(repro.kernels.conv1d_tap) — the paper's technique applied to the 1D
conv inside SSM blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import layers as L

CHUNK = 256


def mamba_init(key, cfg):
    ks = jax.random.split(key, 9)
    D = cfg.d_model
    d_in, H, N, G = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    GN = G * N

    def conv_w(k, dim):
        return (jax.random.normal(k, (cfg.d_conv, dim), jnp.float32)
                * 0.2).astype(L.DEFAULT_DTYPE)

    return {
        "wz": L.dense_init(ks[0], D, d_in),
        "wx": L.dense_init(ks[1], D, d_in),
        "wB": L.dense_init(ks[2], D, GN),
        "wC": L.dense_init(ks[3], D, GN),
        "wdt": L.dense_init(ks[4], D, H),
        "conv_x": {"w": conv_w(ks[5], d_in), "b": jnp.zeros((d_in,),
                                                            L.DEFAULT_DTYPE)},
        "conv_B": {"w": conv_w(ks[6], GN), "b": jnp.zeros((GN,),
                                                          L.DEFAULT_DTYPE)},
        "conv_C": {"w": conv_w(ks[7], GN), "b": jnp.zeros((GN,),
                                                          L.DEFAULT_DTYPE)},
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": L.rmsnorm_init(d_in),
        "out_proj": L.dense_init(ks[8], d_in, D),
    }


def causal_conv1d(x, w, b):
    """Tap-decomposed depthwise causal conv1d (pure-JAX cuConv analogue).

    x: (B, L, C); w: (K, C).  y[l] = sum_k w[k] * x[l - K + 1 + k].
    The K shifted views are XLA slices of one padded buffer — the same
    no-materialized-transform structure as kernels/conv1d_tap.py.
    """
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    Lx = x.shape[1]
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for k in range(K):                        # K taps (K=4): unrolled adds
        y = y + xp[:, k:k + Lx, :].astype(jnp.float32) * w[k].astype(jnp.float32)
    return (y + b.astype(jnp.float32)).astype(x.dtype)


def _conv_decode(window, w, b):
    """window: (B, K, C) raw stream values; returns conv output at last pos."""
    out = (window.astype(jnp.float32) * w.astype(jnp.float32)[None]).sum(1)
    return out + b.astype(jnp.float32)


def _segsum(dA):
    """Stable segment-sum: out[..., i, j] = sum_{j<k<=i} dA[..., k]."""
    T = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk=CHUNK, init_state=None):
    """SSD over chunks, **group-aware**: B/C keep their (g, n) group shape
    inside every einsum instead of being jnp.repeat-ed h-fold up front
    (the repeat materialized two (b, l, h, n) f32 tensors per block — for
    mamba2-1.3b that was 2 x 1.07 GB/layer of pure HBM traffic; §Perf).

    x: (b, l, h, p)  dt: (b, l, h)  A: (h,)  B, C: (b, l, g, n)
    Returns y: (b, l, h, p), final_state: (b, h, p, n).
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert l % chunk == 0, f"seq {l} not divisible by chunk {chunk}"
    nc = l // chunk
    rep = h // g

    xr = x.reshape(b, nc, chunk, g, rep, p)
    dtr = dt.reshape(b, nc, chunk, g, rep)
    Bg = B.reshape(b, nc, chunk, g, n)
    Cg = C.reshape(b, nc, chunk, g, n)

    dA = dtr * A.reshape(g, rep)[None, None, None]   # (b,nc,T,g,rep)
    dA = dA.transpose(0, 1, 3, 4, 2)                 # (b,nc,g,rep,T)
    dA_cum = jnp.cumsum(dA, axis=-1)

    # 1) diagonal (intra-chunk) term; scores are per-GROUP (h-free)
    Ldec = jnp.exp(_segsum(dA))                      # (b,nc,g,rep,T,T)
    scores = jnp.einsum("bctgn,bcsgn->bcgts", Cg, Bg).astype(jnp.float32)
    gated = scores[:, :, :, None] * Ldec             # (b,nc,g,rep,T,T)
    xw = (xr * dtr[..., None]).astype(jnp.float32)   # dt-weighted input
    y_diag = jnp.einsum("bcgrts,bcsgrp->bctgrp", gated, xw)

    # 2) chunk-final states
    decay_to_end = jnp.exp(dA_cum[..., -1:] - dA_cum)        # (b,nc,g,rep,T)
    states = jnp.einsum("bctgn,bcgrt,bctgrp->bcgrpn",
                        Bg.astype(jnp.float32), decay_to_end, xw)

    # 3) inter-chunk recurrence (linear scan over chunks)
    chunk_decay = jnp.exp(dA_cum[..., -1])                    # (b,nc,g,rep)
    s0 = (jnp.zeros((b, g, rep, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32).reshape(b, g, rep, p, n))

    def step(carry, xs):
        st, dec = xs                                  # (b,g,rep,p,n),(b,g,rep)
        new = carry * dec[..., None, None] + st
        return new, carry                             # emit prev state

    final, prev_states = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4, 5),
                   chunk_decay.transpose(1, 0, 2, 3)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4, 5)  # (b,nc,g,rep,p,n)

    # 4) off-diagonal contribution from carried state
    state_decay = jnp.exp(dA_cum)                          # (b,nc,g,rep,T)
    y_off = jnp.einsum("bctgn,bcgrt,bcgrpn->bctgrp",
                       Cg.astype(jnp.float32), state_decay, prev_states)

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, final.reshape(b, h, p, n)


def ssd_decode_step(state, x, dt, A, B, C):
    """Single-token recurrence.  state: (b,h,p,n); x: (b,h,p); B,C: (b,g,n)."""
    b, h, p = x.shape
    g = B.shape[1]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=1).astype(jnp.float32)           # (b,h,n)
    Ch = jnp.repeat(C, rep, axis=1).astype(jnp.float32)
    dA = jnp.exp(dt * A[None, :])                                 # (b,h)
    upd = jnp.einsum("bhp,bhn->bhpn", (x * dt[..., None]).astype(jnp.float32), Bh)
    new_state = state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y, new_state


def mamba_fwd(p, cfg, u, cache=None, mode="train"):
    """u: (B, S, D).

    cache (prefill/decode): ((tail_x, tail_B, tail_C), ssm_state) with
    tails (B, d_conv-1, dim) holding raw pre-conv stream values.
    """
    Bsz, S, _ = u.shape
    d_in, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    z = L.dense_fwd(p["wz"], u)
    x_raw = L.dense_fwd(p["wx"], u)
    B_raw = L.dense_fwd(p["wB"], u)
    C_raw = L.dense_fwd(p["wC"], u)
    dt_raw = L.dense_fwd(p["wdt"], u)

    if mode in ("train", "prefill"):
        x = jax.nn.silu(causal_conv1d(x_raw, p["conv_x"]["w"], p["conv_x"]["b"]))
        Bc = jax.nn.silu(causal_conv1d(B_raw, p["conv_B"]["w"], p["conv_B"]["b"]))
        Cc = jax.nn.silu(causal_conv1d(C_raw, p["conv_C"]["w"], p["conv_C"]["b"]))
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
        A = -jnp.exp(p["A_log"])
        chunk = min(cfg.ssm_chunk or CHUNK, max(16, S))
        pad = (-S) % chunk
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
            Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        y, final_state = ssd_chunked(
            x.reshape(Bsz, -1, H, P), dt, A,
            Bc.reshape(Bsz, -1, G, N), Cc.reshape(Bsz, -1, G, N),
            chunk=chunk)
        y = y.reshape(Bsz, -1, d_in)[:, :S]
        y = y + x[:, :S].astype(jnp.float32) * jnp.repeat(p["D"], P)[None, None, :]
        if mode == "prefill":
            K1 = cfg.d_conv - 1

            def tail(stream, buf):
                t = stream[:, max(0, S - K1):, :]
                if S < K1:
                    t = jnp.pad(t, ((0, 0), (K1 - S, 0), (0, 0)))
                return t.astype(buf.dtype)

            (bx, bB, bC), bs = cache
            new_cache = ((tail(x_raw, bx), tail(B_raw, bB), tail(C_raw, bC)),
                         final_state.astype(bs.dtype))
        else:
            new_cache = None
    else:
        (tx, tB, tC), ssm_state = cache           # tails: (B, K-1, dim)
        win = lambda t, raw: jnp.concatenate(
            [t.astype(raw.dtype), raw[:, :1]], axis=1)
        x = jax.nn.silu(_conv_decode(win(tx, x_raw), p["conv_x"]["w"],
                                     p["conv_x"]["b"])).astype(u.dtype)
        Bc = jax.nn.silu(_conv_decode(win(tB, B_raw), p["conv_B"]["w"],
                                      p["conv_B"]["b"])).astype(u.dtype)
        Cc = jax.nn.silu(_conv_decode(win(tC, C_raw), p["conv_C"]["w"],
                                      p["conv_C"]["b"])).astype(u.dtype)
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
        A = -jnp.exp(p["A_log"])
        y, new_ssm = ssd_decode_step(
            ssm_state.astype(jnp.float32), x.reshape(Bsz, H, P), dt, A,
            Bc.reshape(Bsz, G, N), Cc.reshape(Bsz, G, N))
        y = y.reshape(Bsz, 1, d_in)
        y = y + x.reshape(Bsz, 1, d_in).astype(jnp.float32) \
            * jnp.repeat(p["D"], P)[None, None, :]
        new_tails = tuple(
            jnp.concatenate([t.astype(raw.dtype), raw[:, :1]], axis=1)[:, 1:]
            .astype(t.dtype)
            for t, raw in ((tx, x_raw), (tB, B_raw), (tC, C_raw)))
        new_cache = (new_tails, new_ssm.astype(ssm_state.dtype))

    y = y.astype(u.dtype) * jax.nn.silu(z)
    y = L.rmsnorm_fwd(p["norm"], y, cfg.rms_norm_eps, cfg.norm_impl)
    return L.dense_fwd(p["out_proj"], y), new_cache
