from repro.nn import layers, attention, moe, mamba  # noqa: F401
