"""Core layers: functional init/apply, params as plain dict pytrees.

Convention: ``init_*`` returns a dict of arrays; ``*_fwd`` consumes it.
Sharding metadata is derived from param *paths* in repro.dist.sharding,
so layers stay framework-free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_DTYPE = jnp.bfloat16


def maybe_constrain(x, spec):
    """with_sharding_constraint when a PartitionSpec is given, else no-op.

    Used to pin (batch, seq, d_model) activations at layer boundaries so
    SPMD propagation cannot trade the batch sharding away (it otherwise
    happily replicates batch and feature-shards activations to match the
    FSDP weight layout — observed in the first dry-run iteration).
    """
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def dense_init(key, d_in, d_out, dtype=DEFAULT_DTYPE, bias=False, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_fwd(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_fwd(p, x, eps=1e-5, impl="f32"):
    if impl == "stat_f32":
        # f32 only for the variance reduction; the normalize multiply and
        # scale stay in x.dtype — removes two (B,S,D)-sized f32
        # materializations per call (§Perf memory lever)
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                       keepdims=True)
        inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
        return x * inv * p["scale"].astype(x.dtype)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


def embed_init(key, vocab, d, dtype=DEFAULT_DTYPE):
    w = (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)
    return {"embedding": w}


def embed_fwd(p, ids):
    return jnp.take(p["embedding"], ids, axis=0)


def mlp_init(key, d, d_ff, dtype=DEFAULT_DTYPE):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, d, d_ff, dtype),
        "wg": dense_init(k2, d, d_ff, dtype),
        "wo": dense_init(k3, d_ff, d, dtype),
    }


def mlp_fwd(p, x):
    """SwiGLU MLP (gate * silu(up))."""
    h = jax.nn.silu(dense_fwd(p["wi"], x)) * dense_fwd(p["wg"], x)
    return dense_fwd(p["wo"], h)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)

def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta=1e6, sections=(), impl="f32"):
    """x: (..., L, H, D). positions: (B, L) or (3, B, L) for M-RoPE.

    M-RoPE (qwen2-vl): the head_dim/2 frequency slots are split into
    ``sections`` (t, h, w); each section takes its angle from the matching
    row of the 3-axis position ids.
    impl="bf16": rotate in x.dtype (angles still f32) — avoids promoting
    the whole (B, L, H, D) tensor to f32 (§Perf memory lever).
    """
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)      # (d/2,)
    if positions.ndim == 3 and sections:
        # build per-slot positions from the (3, B, L) grid
        sec_id = np.concatenate([np.full((s,), i) for i, s in enumerate(sections)])
        pos = positions[sec_id]                                  # (d/2, B, L)
        ang = jnp.einsum("sbl,s->bls", pos.astype(jnp.float32), freqs)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs   # (B, L, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if impl == "bf16":
        cos, sin = cos.astype(x.dtype), sin.astype(x.dtype)
    cos = cos[..., None, :]                                      # (B, L, 1, d/2)
    sin = sin[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def make_positions(batch, seq, offset=0):
    return jnp.arange(seq, dtype=jnp.int32)[None, :] + offset + jnp.zeros(
        (batch, 1), jnp.int32)
