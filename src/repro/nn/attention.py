"""Attention mixers: GQA (qwen/mistral/musicgen) and MLA (deepseek-v2).

Three execution paths, all numerically equivalent (tested):
  * exact: full (L x L) causal attention — small seqs;
  * chunked: online-softmax over KV chunks (lax.scan) — bounds memory for
    32k prefill without a kernel; same math as flash attention;
  * decode: one query token against a cached KV (+latent for MLA).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import layers as L

CHUNKED_THRESHOLD = 2048   # switch to online-softmax attention above this
KV_CHUNK = 1024


# ---------------------------------------------------------------------------
# GQA

def gqa_init(key, cfg):
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], cfg.d_model, cfg.q_dim, bias=cfg.qkv_bias),
        "wk": L.dense_init(ks[1], cfg.d_model, cfg.kv_dim, bias=cfg.qkv_bias),
        "wv": L.dense_init(ks[2], cfg.d_model, cfg.kv_dim, bias=cfg.qkv_bias),
        "wo": L.dense_init(ks[3], cfg.q_dim, cfg.d_model),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.rmsnorm_init(cfg.head_dim)
        p["k_norm"] = L.rmsnorm_init(cfg.head_dim)
    return p


def _shard_heads(cfg, t):
    """Pin (B, S, H, head_dim) sharding: hd over 'model' (always divides:
    head_dim 128 % 16 == 0) — rescues archs whose head COUNT does not
    divide the TP degree (qwen3: 40 heads / 16 devices) from SPMD
    resharding storms.  Requires an active mesh context (dry-run/launch);
    no-op otherwise (cfg.shard_heads == 'none', the default)."""
    if cfg.shard_heads != "head_dim":
        return t
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        t, P(None, None, None, "model"))


def _qkv(p, cfg, x, positions):
    B, S, _ = x.shape
    q = L.dense_fwd(p["wq"], x).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = L.dense_fwd(p["wk"], x).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = L.dense_fwd(p["wv"], x).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rmsnorm_fwd(p["q_norm"], q, cfg.rms_norm_eps, cfg.norm_impl)
        k = L.rmsnorm_fwd(p["k_norm"], k, cfg.rms_norm_eps, cfg.norm_impl)
    q = L.apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections,
                     cfg.rope_impl)
    k = L.apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections,
                     cfg.rope_impl)
    q, k, v = _shard_heads(cfg, q), _shard_heads(cfg, k), _shard_heads(cfg, v)
    return q, k, v


def _repeat_kv(k, num_heads):
    """(B, S, KVH, D) -> (B, S, H, D) by head-group broadcast."""
    B, S, KVH, D = k.shape
    rep = num_heads // KVH
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, KVH, rep, D)).reshape(
        B, S, num_heads, D)


def exact_attention(q, k, v, causal=True, q_offset=0):
    """q: (B,Sq,H,D); k,v: (B,Sk,H,D). f32 softmax accumulation."""
    D = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(D).astype(jnp.float32)
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        qi = jnp.arange(Sq)[:, None] + q_offset
        ki = jnp.arange(Sk)[None, :]
        scores = jnp.where(qi >= ki, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def chunked_attention(q, k, v, causal=True, chunk=KV_CHUNK, unroll=False,
                      score_dtype=jnp.float32):
    """Online-softmax attention over KV chunks: O(Sq * chunk) live memory.

    Mathematically identical to exact_attention (flash-attention recurrence);
    this is the pure-XLA twin of kernels/flash_attention.py.
    unroll=True replaces the lax.scan with a Python loop (used by the
    dry-run cost probes: XLA's HloCostAnalysis counts while bodies once).
    score_dtype=bf16 keeps the (Sq x chunk) score/prob tensors in bf16 at
    HBM boundaries (the exp/max arithmetic stays f32 inside fusions) —
    §Perf memory lever; running max/denominator/accumulator remain f32.
    """
    B, Sq, H, D = q.shape
    Dv = v.shape[-1]
    Sk = k.shape[1]
    nchunks = (Sk + chunk - 1) // chunk
    pad = nchunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunks, chunk, H, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunks, chunk, H, Dv).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    qi = jnp.arange(Sq)[:, None]
    NEG = jnp.finfo(score_dtype).min / 2

    def step(carry, xs):
        m, l, acc, ci = carry[0], carry[1], carry[2], carry[3]
        kb, vb = xs
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb,
                       preferred_element_type=score_dtype)
        s = (s * scale.astype(score_dtype)).astype(score_dtype)
        ki = ci * chunk + jnp.arange(chunk)[None, :]
        mask = ki < Sk
        if causal:
            mask = mask & (qi >= ki)
        s = jnp.where(mask[None, None], s, NEG)
        m_new = jnp.maximum(m, s.max(axis=-1).astype(jnp.float32))
        # guard all-masked rows (m_new = NEG): contribute nothing
        m_safe = jnp.where(m_new > NEG / 2, m_new, 0.0)
        p = jnp.exp(s.astype(jnp.float32) - m_safe[..., None]).astype(
            score_dtype)
        p = jnp.where(mask[None, None], p, 0)
        corr = jnp.where(m > NEG / 2, jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + p.astype(jnp.float32).sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vb).astype(jnp.float32)
        return (m_new, l_new, acc_new, ci + 1), None

    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, Dv), jnp.float32)
    if unroll:
        carry = (m0, l0, a0, jnp.int32(0))
        for ci in range(nchunks):
            carry, _ = step(carry, (kc[ci], vc[ci]))
        m, l, acc = carry[0], carry[1], carry[2]
    else:
        (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, jnp.int32(0)),
                                         (kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)     # (B, Sq, H, D)


def gqa_fwd(p, cfg, x, positions, cache=None, offset=0, mode="train"):
    """Returns (out, new_cache).

    mode: "train" (no cache), "prefill" (attend within batch, write cache
    buffer at ``offset``), "decode" (attend against the cache).
    cache: (k_buf, v_buf) of shape (B, Lmax, KVH, D) for prefill/decode.
    """
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    if mode in ("train", "prefill"):
        kf = _repeat_kv(k, cfg.num_heads)
        vf = _repeat_kv(v, cfg.num_heads)
        if S > CHUNKED_THRESHOLD and cfg.attn_impl != "exact":
            out = chunked_attention(
                q, kf, vf, unroll=(cfg.attn_impl == "chunked_unrolled"),
                score_dtype=(jnp.bfloat16 if cfg.attn_score_dtype == "bf16"
                             else jnp.float32))
        else:
            out = exact_attention(q, kf, vf)
        if mode == "prefill":
            ck, cv = cache
            ck = jax.lax.dynamic_update_slice_in_dim(
                ck, k.astype(ck.dtype), offset, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cv, v.astype(cv.dtype), offset, 1)
            new_cache = (ck, cv)
        else:
            new_cache = None
    else:
        ck, cv = cache                             # (B, Lmax, KVH, D) x2
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), offset, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), offset, 1)
        kf = _repeat_kv(ck, cfg.num_heads)
        vf = _repeat_kv(cv, cfg.num_heads)
        Lmax = ck.shape[1]
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kf).astype(jnp.float32)
        scores = scores / jnp.sqrt(cfg.head_dim).astype(jnp.float32)
        ki = jnp.arange(Lmax)[None, :]
        qi = offset + jnp.arange(S)[:, None]
        scores = jnp.where((ki <= qi)[None, None], scores, -jnp.inf)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", w, vf)
        new_cache = (ck, cv)
    out = out.reshape(B, S, cfg.q_dim)
    return L.dense_fwd(p["wo"], out), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): latent-compressed KV.

def mla_init(key, cfg):
    ks = jax.random.split(key, 4)
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq": L.dense_init(ks[0], cfg.d_model, cfg.num_heads * qk_dim),
        "w_dkv": L.dense_init(ks[1], cfg.d_model,
                              cfg.kv_lora_rank + cfg.qk_rope_dim),
        "kv_norm": L.rmsnorm_init(cfg.kv_lora_rank),
        "w_ukv": L.dense_init(
            ks[2], cfg.kv_lora_rank,
            cfg.num_heads * (cfg.qk_nope_dim + cfg.v_head_dim)),
        "wo": L.dense_init(ks[3], cfg.num_heads * cfg.v_head_dim, cfg.d_model),
    }


def _mla_qkv(p, cfg, x, positions, latent):
    """latent: (B, S_total, lora+rope) compressed cache (or None)."""
    B, S, _ = x.shape
    H = cfg.num_heads
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    q = L.dense_fwd(p["wq"], x).reshape(B, S, H, qk_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta,
                          impl=cfg.rope_impl)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    ckv = L.dense_fwd(p["w_dkv"], x)                       # (B,S,lora+rope)
    c_kv, k_rope = jnp.split(ckv, [cfg.kv_lora_rank], axis=-1)
    c_kv = L.rmsnorm_fwd(p["kv_norm"], c_kv, cfg.rms_norm_eps,
                         cfg.norm_impl)
    k_rope = L.apply_rope(k_rope[:, :, None, :], positions,
                          cfg.rope_theta, impl=cfg.rope_impl)
    new_latent = jnp.concatenate([c_kv, k_rope[:, :, 0, :]], axis=-1)
    return q, new_latent


def _mla_expand(p, cfg, latent):
    """Expand latent cache -> per-head K (nope+rope) and V."""
    B, S, _ = latent.shape
    H = cfg.num_heads
    c_kv, k_rope = jnp.split(latent, [cfg.kv_lora_rank], axis=-1)
    kv = L.dense_fwd(p["w_ukv"], c_kv).reshape(
        B, S, H, cfg.qk_nope_dim + cfg.v_head_dim)
    k_nope, v = jnp.split(kv, [cfg.qk_nope_dim], axis=-1)
    k_rope = jnp.broadcast_to(k_rope[:, :, None, :],
                              (B, S, H, cfg.qk_rope_dim))
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    return k, v


def mla_fwd(p, cfg, x, positions, cache=None, offset=0, mode="train"):
    B, S, _ = x.shape
    q, latent = _mla_qkv(p, cfg, x, positions, None)
    if mode in ("train", "prefill"):
        k, v = _mla_expand(p, cfg, latent)
        if S > CHUNKED_THRESHOLD and cfg.attn_impl != "exact":
            out = chunked_attention(
                q, k, v, unroll=(cfg.attn_impl == "chunked_unrolled"),
                score_dtype=(jnp.bfloat16 if cfg.attn_score_dtype == "bf16"
                             else jnp.float32))
        else:
            out = exact_attention(q, k, v)
        if mode == "prefill":
            new_cache = jax.lax.dynamic_update_slice_in_dim(
                cache, latent.astype(cache.dtype), offset, 1)
        else:
            new_cache = None
    else:
        clat = cache                                       # (B, Lmax, lora+rope)
        clat = jax.lax.dynamic_update_slice_in_dim(
            clat, latent.astype(clat.dtype), offset, 1)
        k, v = _mla_expand(p, cfg, clat)
        Lmax = clat.shape[1]
        scale = 1.0 / jnp.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
        ki = jnp.arange(Lmax)[None, :]
        qi = offset + jnp.arange(S)[:, None]
        scores = jnp.where((ki <= qi)[None, None], scores, -jnp.inf)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", w, v)
        new_cache = clat
    out = out.reshape(B, S, cfg.num_heads * cfg.v_head_dim)
    return L.dense_fwd(p["wo"], out), new_cache
