from repro.models import lm, cnn  # noqa: F401
