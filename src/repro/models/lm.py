"""Causal LM assembly for all assigned architecture families.

The layer stack is described by a *stack plan*: a list of segments, each
``(repeats, kinds)`` where ``kinds`` is the repeating period of
(mixer, mlp) pairs.  Uniform models have one segment of period 1 and are
``lax.scan``-ed over all layers (keeps HLO small enough to compile 88-layer
models for 512 SPMD devices on one CPU core).  Jamba scans over 4 repeats
of its 8-layer period; deepseek-moe unrolls its dense first layer and
scans the remaining 27 MoE layers.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, SSM, DENSE, MOE, ModelConfig
from repro.nn import layers as L
from repro.nn import attention as A
from repro.nn import moe as M
from repro.nn import mamba as S


# ---------------------------------------------------------------------------
# Stack plan

def stack_plan(cfg: ModelConfig) -> List[Tuple[int, Tuple[Tuple[str, str], ...]]]:
    kinds = cfg.layer_kinds()
    n = cfg.num_layers
    if cfg.first_layer_dense:
        rest = kinds[1:]
        assert all(k == rest[0] for k in rest), "unsupported irregular stack"
        return [(1, (kinds[0],)), (n - 1, (rest[0],))]
    p = cfg.pattern_period
    if p == 0:
        return [(1, (k,)) for k in kinds]          # fully unrolled
    period = kinds[:p]
    assert kinds == period * (n // p)
    return [(n // p, period)]


# ---------------------------------------------------------------------------
# Per-layer init / fwd

def _layer_init(key, cfg, mixer, mlp):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": L.rmsnorm_init(cfg.d_model)}
    if mixer == ATTN:
        p["attn"] = A.mla_init(ks[0], cfg) if cfg.mla else A.gqa_init(ks[0], cfg)
    else:
        p["ssm"] = S.mamba_init(ks[0], cfg)
    if mlp != "none":
        p["ln2"] = L.rmsnorm_init(cfg.d_model)
        if mlp == MOE:
            p["moe"] = M.moe_init(ks[1], cfg)
        else:
            p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff)
    return p


def _layer_fwd(p, cfg, mixer, mlp, x, positions, cache, offset, mode,
               moe_groups=1):
    h = L.rmsnorm_fwd(p["ln1"], x, cfg.rms_norm_eps, cfg.norm_impl)
    aux = {}
    if mixer == ATTN:
        fwd = A.mla_fwd if cfg.mla else A.gqa_fwd
        out, new_cache = fwd(p["attn"], cfg, h, positions, cache, offset, mode)
    else:
        out, new_cache = S.mamba_fwd(p["ssm"], cfg, h, cache, mode)
    x = x + out
    if mlp != "none":
        h2 = L.rmsnorm_fwd(p["ln2"], x, cfg.rms_norm_eps, cfg.norm_impl)
        if mlp == MOE:
            mo, aux = M.moe_fwd(p["moe"], cfg, h2,
                                dropless=(mode == "decode"),
                                n_groups=moe_groups)
        else:
            mo = L.mlp_fwd(p["mlp"], h2)
        x = x + mo
    return x, new_cache, aux


def _period_init(key, cfg, kinds):
    ks = jax.random.split(key, len(kinds))
    return {f"pos{i}": _layer_init(ks[i], cfg, mx, ml)
            for i, (mx, ml) in enumerate(kinds)}


def _period_fwd(p, cfg, kinds, x, positions, caches, offset, mode,
                moe_groups=1):
    new_caches, aux_sum = {}, jnp.zeros((), jnp.float32)
    dropped = jnp.zeros((), jnp.float32)
    for i, (mx, ml) in enumerate(kinds):
        c = caches.get(f"pos{i}") if caches is not None else None
        x, nc, aux = _layer_fwd(p[f"pos{i}"], cfg, mx, ml, x, positions, c,
                                offset, mode, moe_groups)
        new_caches[f"pos{i}"] = nc
        if aux:
            aux_sum = aux_sum + aux["load_balance_loss"]
            dropped = dropped + aux["dropped_frac"]
    return x, new_caches, {"load_balance_loss": aux_sum, "dropped_frac": dropped}


# ---------------------------------------------------------------------------
# Model init

def init_lm(cfg: ModelConfig, key) -> Dict[str, Any]:
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        params["embed"] = L.embed_init(keys[0], cfg.padded_vocab, cfg.d_model)
    params["segments"] = []
    for si, (repeats, kinds) in enumerate(stack_plan(cfg)):
        seg_keys = jax.random.split(keys[1 + (si % 6)], repeats)
        if repeats == 1:
            seg = _period_init(seg_keys[0], cfg, kinds)
            seg = jax.tree.map(lambda a: a[None], seg)     # repeats dim = 1
        else:
            seg = jax.vmap(lambda k: _period_init(k, cfg, kinds))(seg_keys)
        params["segments"].append(seg)
    params["final_norm"] = L.rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[7], cfg.d_model, cfg.padded_vocab)
    return params


# ---------------------------------------------------------------------------
# Cache init

def _layer_cache_shapes(cfg, mixer, batch, max_len, kv_dtype):
    if mixer == ATTN:
        if cfg.mla:
            return jax.ShapeDtypeStruct(
                (batch, max_len, cfg.kv_lora_rank + cfg.qk_rope_dim),
                kv_dtype)
        return (
            jax.ShapeDtypeStruct((batch, max_len, cfg.num_kv_heads,
                                  cfg.head_dim), kv_dtype),
            jax.ShapeDtypeStruct((batch, max_len, cfg.num_kv_heads,
                                  cfg.head_dim), kv_dtype),
        )
    gn = cfg.ssm_groups * cfg.ssm_state
    return (
        (jax.ShapeDtypeStruct((batch, cfg.d_conv - 1, cfg.d_inner), kv_dtype),
         jax.ShapeDtypeStruct((batch, cfg.d_conv - 1, gn), kv_dtype),
         jax.ShapeDtypeStruct((batch, cfg.d_conv - 1, gn), kv_dtype)),
        jax.ShapeDtypeStruct((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                              cfg.ssm_state), jnp.float32),
    )


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int,
                 kv_dtype=jnp.bfloat16):
    out = []
    for repeats, kinds in stack_plan(cfg):
        seg = {}
        for i, (mx, _) in enumerate(kinds):
            shapes = _layer_cache_shapes(cfg, mx, batch, max_len, kv_dtype)
            seg[f"pos{i}"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((repeats,) + s.shape, s.dtype),
                shapes, is_leaf=lambda s: isinstance(s, jax.ShapeDtypeStruct))
        out.append(seg)
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               kv_dtype=jnp.bfloat16):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_shapes(cfg, batch, max_len, kv_dtype),
                        is_leaf=lambda s: isinstance(s, jax.ShapeDtypeStruct))


# ---------------------------------------------------------------------------
# Forward

def lm_forward(params, cfg: ModelConfig, batch: Dict[str, Any],
               cache=None, offset=0, mode="train", act_spec=None,
               moe_groups=1, skip_head=False):
    """Returns (logits, new_cache, aux).

    batch: {'tokens': (B,S) int32} or {'embeds': (B,S,D)}; optional
    'positions' ((B,S) or (3,B,S) for M-RoPE).
    mode: "train" | "prefill" | "decode".
    act_spec: optional PartitionSpec for (B, S, D) activations, pinned at
    every layer boundary (see nn.layers.maybe_constrain).
    """
    if cfg.input_mode == "tokens":
        x = L.embed_fwd(params["embed"], batch["tokens"])
        B, Sq = batch["tokens"].shape
    else:
        # match the params' compute dtype (tests may cast params to f32)
        pdt = (params["lm_head"]["w"].dtype if "lm_head" in params
               else L.DEFAULT_DTYPE)
        x = batch["embeds"].astype(pdt)
        B, Sq = x.shape[0], x.shape[1]
    x = L.maybe_constrain(x, act_spec)
    positions = batch.get("positions")
    if positions is None:
        positions = L.make_positions(B, Sq, offset)

    new_cache_out, aux_tot = [], {"load_balance_loss": jnp.zeros((), jnp.float32),
                                  "dropped_frac": jnp.zeros((), jnp.float32)}
    for si, (repeats, kinds) in enumerate(stack_plan(cfg)):
        seg_params = params["segments"][si]
        seg_cache = cache[si] if cache is not None else None

        def period_body(x_, p_, c_):
            x_ = L.maybe_constrain(x_, act_spec)
            out = _period_fwd(p_, cfg, kinds, x_, positions, c_, offset,
                              mode, moe_groups)
            return (L.maybe_constrain(out[0], act_spec),) + out[1:]

        if cfg.remat == "full":
            period_body = jax.checkpoint(period_body)
        elif cfg.remat == "dots":
            # save matmul outputs, recompute the cheap elementwise rest:
            # trades the full-remat fwd replay (~8ND) for extra activation
            # memory (§Perf lever)
            period_body = jax.checkpoint(
                period_body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

        if cfg.scan_layers:
            def scan_step(carry, xs):
                x_, aux_ = carry
                p_, c_ = xs
                x_, nc_, aux_i = period_body(x_, p_, c_)
                aux_ = jax.tree.map(lambda a, b: a + b, aux_, aux_i)
                return (x_, aux_), nc_

            (x, aux_tot), seg_new_cache = jax.lax.scan(
                scan_step, (x, aux_tot), (seg_params, seg_cache))
        else:
            # unrolled (dry-run cost probes: while bodies are counted once
            # by HloCostAnalysis, so probes must not hide layers in a scan)
            caches_r = []
            for r in range(repeats):
                p_r = jax.tree.map(lambda a: a[r], seg_params)
                c_r = (jax.tree.map(lambda a: a[r], seg_cache)
                       if seg_cache is not None else None)
                x, nc_r, aux_i = period_body(x, p_r, c_r)
                aux_tot = jax.tree.map(lambda a, b: a + b, aux_tot, aux_i)
                caches_r.append(nc_r)
            seg_new_cache = jax.tree.map(
                lambda *ls: jnp.stack(ls, 0), *caches_r)
        new_cache_out.append(seg_new_cache)

    x = L.rmsnorm_fwd(params["final_norm"], x, cfg.rms_norm_eps,
                      cfg.norm_impl)
    if skip_head:
        return x, new_cache_out, aux_tot
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["embedding"].T
    else:
        logits = L.dense_fwd(params["lm_head"], x)
    return logits, new_cache_out, aux_tot


# ---------------------------------------------------------------------------
# Losses / steps

def cross_entropy(logits, labels, vocab_size):
    """Mean CE over tokens; logits (B,S,Vpad), labels (B,S) in [0, vocab)."""
    lf = logits.astype(jnp.float32)
    # mask padded vocab slots out of the partition function
    Vpad = lf.shape[-1]
    if Vpad > vocab_size:
        neg = jnp.full((Vpad - vocab_size,), -1e30, jnp.float32)
        lf = jnp.concatenate(
            [lf[..., :vocab_size],
             jnp.broadcast_to(neg, lf.shape[:-1] + (Vpad - vocab_size,))],
            axis=-1)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()


def cross_entropy_chunked(hidden, head_w, labels, vocab_size,
                          chunk=512, unroll=False):
    """Fused head+CE over sequence chunks: the full (B,S,Vpad) f32 logits
    tensor is never materialized — each chunk's logits are produced,
    reduced to (logz - gold) and discarded (with recompute on the bwd via
    jax.checkpoint).  §Perf memory-term lever; numerics identical to
    cross_entropy (tested).

    hidden: (B,S,D); head_w: (D, Vpad); labels: (B,S).
    """
    B, S, D = hidden.shape
    Vpad = head_w.shape[1]
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    nch = (S + pad) // chunk
    hc = hidden.reshape(B, nch, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nch, chunk).transpose(1, 0, 2)
    valid = (jnp.arange(S + pad) < S).reshape(nch, chunk)

    @jax.checkpoint
    def chunk_ce(xc, yc, vc):
        logits = (xc @ head_w).astype(jnp.float32)       # (B, chunk, Vpad)
        if Vpad > vocab_size:
            col = jnp.arange(Vpad) < vocab_size
            logits = jnp.where(col, logits, -1e30)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return jnp.sum((logz - gold) * vc[None, :])

    if unroll:
        total = jnp.zeros((), jnp.float32)
        for i in range(nch):
            total = total + chunk_ce(hc[i], lc[i], valid[i])
    else:
        def body(carry, xs):
            xc, yc, vc = xs
            return carry + chunk_ce(xc, yc, vc), None
        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                (hc, lc, valid))
    return total / (B * S)


def train_loss(params, cfg: ModelConfig, batch, act_spec=None,
               moe_groups=1):
    if cfg.ce_impl == "chunked":
        hidden, _, aux = lm_forward(params, cfg, batch, act_spec=act_spec,
                                    moe_groups=moe_groups, skip_head=True)
        head_w = (params["embed"]["embedding"].T if cfg.tie_embeddings
                  else params["lm_head"]["w"])
        loss = cross_entropy_chunked(
            hidden, head_w, batch["labels"], cfg.vocab_size,
            unroll=(cfg.attn_impl == "chunked_unrolled"))
    else:
        logits, _, aux = lm_forward(params, cfg, batch, act_spec=act_spec,
                                    moe_groups=moe_groups)
        loss = cross_entropy(logits, batch["labels"], cfg.vocab_size)
    if cfg.num_experts:
        loss = loss + 0.01 * aux["load_balance_loss"]
    return loss, {"ce_loss": loss, **aux}


def prefill(params, cfg: ModelConfig, batch, cache, act_spec=None,
            moe_groups=1):
    """Run the full prompt, writing into a preallocated decode cache."""
    logits, new_cache, _ = lm_forward(params, cfg, batch, cache=cache,
                                      offset=0, mode="prefill",
                                      act_spec=act_spec,
                                      moe_groups=moe_groups)
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, batch, cache, offset,
                act_spec=None):
    """One token step against an existing cache."""
    logits, new_cache, _ = lm_forward(params, cfg, batch, cache=cache,
                                      offset=offset, mode="decode",
                                      act_spec=act_spec)
    return logits, new_cache


def _batch_size(cfg, batch):
    return (batch["tokens"].shape[0] if cfg.input_mode == "tokens"
            else batch["embeds"].shape[0])
