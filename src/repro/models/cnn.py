"""CNN inference graphs over the cuConv core (the paper's own domain).

The paper evaluates standalone convolution configurations drawn from five
CNNs; this module provides a runnable sequential CNN whose conv stack is
planned as ONE program through the graph layer (core/graph.py): a
``SimpleCNN`` resolves a ``GraphPlan`` per input geometry exactly once
(memoized, and persisted across processes via the graph-level cache) and
every ``apply`` executes that pre-resolved program — no per-call-site
re-planning inside the conv blocks.  ``conv_block`` remains as the eager
one-off path for standalone layer experiments.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cuconv
from repro.core.graph import ConvGraph, GraphPlan, plan_graph


def init_conv(key, kh, kw, c_in, c_out, dtype=jnp.float32):
    scale = 1.0 / np.sqrt(kh * kw * c_in)
    k1, k2 = jax.random.split(key)
    return {
        "w": jax.random.normal(k1, (kh, kw, c_in, c_out), dtype) * scale,
        "b": jnp.zeros((c_out,), dtype),
    }


def conv_block(p, x, stride=1, padding="same", algorithm="auto"):
    # eager per-call path: bias+ReLU ride the conv as a planned epilogue
    # (fused in VMEM on the Pallas path, plain XLA ops elsewhere).  Model
    # inference goes through the pre-resolved GraphPlan instead.
    return cuconv.conv2d(x, p["w"], stride, padding, algorithm,
                         bias=p["b"], activation="relu")


def maxpool(x, k=2, s=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, s, s, 1), "VALID")


class SimpleCNN:
    """Sequential conv stack + GAP head; spec: [(kh, kw, c_out, stride), ...].

    The conv stack is a plannable program: ``graph_plan(in_shape)``
    resolves (once per geometry/backend) and ``apply`` executes it.
    """

    def __init__(self, spec: Sequence[Tuple[int, int, int, int]],
                 num_classes: int = 10, in_channels: int = 3):
        self.spec, self.num_classes, self.in_channels = (
            tuple(spec), num_classes, in_channels)
        self._plan_cache: Dict[tuple, GraphPlan] = {}

    def init(self, key):
        params: List = []
        c = self.in_channels
        keys = jax.random.split(key, len(self.spec) + 1)
        for i, (kh, kw, co, s) in enumerate(self.spec):
            params.append(init_conv(keys[i], kh, kw, c, co))
            c = co
        head = (jax.random.normal(keys[-1], (c, self.num_classes), jnp.float32)
                / np.sqrt(c))
        return {"convs": params, "head": head}

    # -- graph planning --------------------------------------------------
    def graph(self, in_shape, dtype: str = "float32") -> ConvGraph:
        """The conv skeleton for one input geometry (bias_relu epilogue —
        what every conv block of this model computes)."""
        return ConvGraph.chain(self.spec, in_shape, dtype=dtype)

    def graph_plan(self, in_shape, *, backend: Optional[str] = None,
                   force: Optional[str] = None,
                   dtype: str = "float32") -> GraphPlan:
        """The whole-network plan for one input geometry, resolved once
        per (geometry, backend, force) and memoized on the model."""
        backend = backend or jax.default_backend()
        key = (tuple(map(int, in_shape)), backend, force, dtype)
        gp = self._plan_cache.get(key)
        if gp is None:
            gp = plan_graph(self.graph(in_shape, dtype=dtype),
                            backend=backend, force=force)
            self._plan_cache[key] = gp
        return gp

    # -- execution -------------------------------------------------------
    def apply(self, params, x, algorithm="auto",
              graph_plan: Optional[GraphPlan] = None):
        """Run the planned program.  ``algorithm`` other than "auto"
        forces that algorithm for every node (capability-guarded);
        passing ``graph_plan`` skips the memo entirely (serving engines
        hold their own per-bucket plans)."""
        gp = graph_plan or self.graph_plan(
            x.shape, force=None if algorithm == "auto" else algorithm,
            dtype=str(x.dtype))
        x = gp.run(x, [(p["w"], p["b"]) for p in params["convs"]])
        x = x.mean(axis=(1, 2))                       # global average pool
        return x @ params["head"]


def squeezenet_like():
    """Small SqueezeNet-flavoured stack (1x1-heavy: cuConv's best region)."""
    return SimpleCNN([
        (3, 3, 64, 2),
        (1, 1, 16, 1), (1, 1, 64, 1), (3, 3, 64, 1),
        (1, 1, 32, 1), (1, 1, 128, 1), (3, 3, 128, 1),
        (1, 1, 48, 1), (1, 1, 192, 1), (3, 3, 192, 1),
    ])
