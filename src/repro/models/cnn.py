"""CNN inference graphs over the cuConv core (the paper's own domain).

The paper evaluates convolution configurations drawn from five real
CNNs (AlexNet, GoogLeNet, ResNet, SqueezeNet, VGG); this module builds
runnable networks of that shape whose ENTIRE forward pass — convs,
pooling, residual adds, fire-module concats, depthwise stages, GAP +
dense head — is one typed-IR program planned through the graph layer
(core/graph.py).  A model resolves a ``GraphPlan`` per input geometry
exactly once (memoized, and persisted across processes via the
graph-level cache) and every ``apply`` executes that pre-resolved
program: no per-call-site re-planning anywhere, observable via
``convspec.PLAN_STATS``.

``GraphModel`` is the generic carrier (name-keyed params mirroring the
IR's node names); ``SimpleCNN`` keeps the chain-era list-of-layers
interface on top of it; ``resnet_like``/``mobilenet_like``/``fire_like``
exercise the operator kinds the paper's networks need.  ``conv_block``
remains as the eager one-off path for standalone layer experiments.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cuconv
from repro.core.graph import (ConvOp, DenseOp, Graph, GraphBuilder,
                              GraphPlan, PrecisionPolicy, plan_graph)


def init_conv(key, kh, kw, c_in, c_out, dtype=jnp.float32):
    scale = 1.0 / np.sqrt(kh * kw * c_in)
    k1, k2 = jax.random.split(key)
    return {
        "w": jax.random.normal(k1, (kh, kw, c_in, c_out), dtype) * scale,
        "b": jnp.zeros((c_out,), dtype),
    }


def conv_block(p, x, stride=1, padding="same", algorithm="auto"):
    # eager per-call path: bias+ReLU ride the conv as a planned epilogue
    # (fused in VMEM on the Pallas path, plain XLA ops elsewhere).  Model
    # inference goes through the pre-resolved GraphPlan instead.
    return cuconv.conv2d(x, p["w"], stride, padding, algorithm,
                         bias=p["b"], activation="relu")


def maxpool(x, k=2, s=2):
    # eager standalone pooling; the IR's PoolOp nodes run the same
    # executor inside planned programs
    from repro.kernels import ops
    return ops.pool2d(x, "max", (k, k), (s, s))


# ---------------------------------------------------------------------------
# generic IR-backed model

class GraphModel:
    """A CNN whose whole forward pass is one planned Graph program.

    ``builder(in_shape, precision) -> Graph`` defines the architecture
    for one input geometry (``precision`` is a ``PrecisionPolicy`` —
    ``GraphBuilder`` accepts it wherever a dtype string went); params
    are a name-keyed dict mirroring the IR (``{node_name: {"w": ...,
    "b": ...}}`` for conv and dense nodes).  Param shapes are
    geometry-independent (GAP decouples the head from the spatial
    extent), so ``init`` builds the graph once at the model's canonical
    ``image_shape``.  Master params are always fp32; a bf16 policy casts
    at the planned conv nodes (fp32 accumulation per the executors'
    declarations).
    """

    def __init__(self, builder: Callable[[Tuple[int, ...], str], Graph],
                 image_shape: Tuple[int, int, int], name: str = "graph_cnn",
                 precision=None):
        self.builder = builder
        self.image_shape = tuple(map(int, image_shape))     # (H, W, C)
        self.name = name
        # model-level default policy; None defers to the input dtype
        self.precision = (None if precision is None
                          else PrecisionPolicy.of(precision))
        self._plan_cache: Dict[tuple, GraphPlan] = {}

    def _policy(self, precision=None, dtype=None) -> PrecisionPolicy:
        """Effective policy: per-call precision > model default > the
        legacy per-call dtype string (derived from the input array)."""
        if precision is not None:
            return PrecisionPolicy.of(precision)
        if self.precision is not None:
            return self.precision
        return PrecisionPolicy.of(dtype)

    # -- graph planning --------------------------------------------------
    def graph(self, in_shape, dtype: str = "float32",
              precision=None) -> Graph:
        """The whole-network IR for one input geometry."""
        pol = self._policy(precision, dtype)
        return self.builder(tuple(map(int, in_shape)), pol)

    def graph_plan(self, in_shape, *, backend: Optional[str] = None,
                   force: Optional[str] = None, dtype: str = "float32",
                   precision=None, fuse: bool = True) -> GraphPlan:
        """The whole-network plan for one input geometry, resolved once
        per (geometry, backend, force, precision, fuse) and memoized on
        the model.  ``fuse=False`` serves the unfused program (the
        cross-layer fusion pass is on by default).

        A ``quant.QuantPolicy`` rides the same ``precision=`` parameter
        (it IS a PrecisionPolicy): the int8 quantize pass runs inside
        ``plan_graph``, and the memo key carries the calibration
        generation so a recalibration re-quantizes instead of serving a
        plan built on stale scales."""
        backend = backend or jax.default_backend()
        pol = self._policy(precision, dtype)
        quant = pol.quantizer()
        key = (tuple(map(int, in_shape)), backend, force, pol.key(), fuse)
        if quant is not None:
            from repro.quant import calibrate
            key = key + (calibrate.generation(),)
        gp = self._plan_cache.get(key)
        if gp is None:
            gp = plan_graph(self.graph(in_shape, precision=pol),
                            backend=backend, force=force, fuse=fuse,
                            quant=quant)
            self._plan_cache[key] = gp
        return gp

    # -- params ----------------------------------------------------------
    def init(self, key):
        """Name-keyed params for every conv/dense node of the graph."""
        graph = self.graph((1,) + self.image_shape)
        needy = [n for n in graph.nodes if isinstance(n, (ConvOp, DenseOp))]
        keys = jax.random.split(key, max(len(needy), 1))
        params: Dict[str, Dict] = {}
        for k, node in zip(keys, needy):
            if isinstance(node, ConvOp):
                kh, kw, cpg, m = node.spec.filter_shape
                p = init_conv(k, kh, kw, cpg, m)
                if not node.spec.has_bias:
                    del p["b"]
            else:
                c_in, c_out = node.features
                p = {"w": jax.random.normal(k, (c_in, c_out), jnp.float32)
                     / np.sqrt(c_in)}
                if node.bias:
                    p["b"] = jnp.zeros((c_out,), jnp.float32)
            params[node.name] = p
        return params

    # -- execution -------------------------------------------------------
    def apply(self, params, x, algorithm="auto",
              graph_plan: Optional[GraphPlan] = None, precision=None):
        """Run the planned program.  ``algorithm`` other than "auto"
        forces that registered executor for every conv node, subject to
        each executor's declared capabilities — on a network with
        grouped/depthwise nodes, forcing an executor that cannot run
        them raises (force "lax" or use "auto"); ``precision`` overrides
        the model's PrecisionPolicy for this call; passing ``graph_plan``
        skips the memo entirely (serving engines hold their own
        per-bucket plans)."""
        gp = graph_plan or self.graph_plan(
            x.shape, force=None if algorithm == "auto" else algorithm,
            dtype=str(x.dtype), precision=precision)
        return gp.run(x, params)


# ---------------------------------------------------------------------------
# chain-era interface, now lowered onto the IR

class SimpleCNN(GraphModel):
    """Sequential conv stack + GAP head; spec: [(kh, kw, c_out, stride), ...].

    The WHOLE forward pass (conv chain, GAP, head) is one plannable
    program (planning/memoization inherited from GraphModel).  Params
    keep the chain-era layout (``{"convs": [...], "head": matrix}``)
    and are mapped onto the IR's node names inside ``apply``.
    """

    def __init__(self, spec: Sequence[Tuple[int, int, int, int]],
                 num_classes: int = 10, in_channels: int = 3):
        self.spec, self.num_classes, self.in_channels = (
            tuple(spec), num_classes, in_channels)
        super().__init__(self._build, (32, 32, in_channels),
                         name="simple_cnn")

    def _build(self, in_shape, dtype: str) -> Graph:
        """The whole-network IR for one input geometry: the conv chain
        (bias_relu epilogue per block, node names matching what
        ``ConvGraph.chain(...).to_ir()`` produces) plus GAP + dense head."""
        b = GraphBuilder(in_shape, dtype)
        y = "input"
        for i, (kh, kw, co, s) in enumerate(self.spec):
            y = b.conv(f"conv{i}", y, (kh, kw), co, stride=s)
        y = b.gap("gap", y)
        b.dense("head", y, self.num_classes, bias=False)
        return b.graph()

    def init(self, key):
        params: List = []
        c = self.in_channels
        keys = jax.random.split(key, len(self.spec) + 1)
        for i, (kh, kw, co, s) in enumerate(self.spec):
            params.append(init_conv(keys[i], kh, kw, c, co))
            c = co
        head = (jax.random.normal(keys[-1], (c, self.num_classes), jnp.float32)
                / np.sqrt(c))
        return {"convs": params, "head": head}

    def apply(self, params, x, algorithm="auto",
              graph_plan: Optional[GraphPlan] = None, precision=None):
        """Run the planned program (see GraphModel.apply)."""
        named = {f"conv{i}": p for i, p in enumerate(params["convs"])}
        named["head"] = {"w": params["head"]}
        return super().apply(named, x, algorithm, graph_plan, precision)


# ---------------------------------------------------------------------------
# model builders: the operator kinds the paper's networks need

def squeezenet_like():
    """Small SqueezeNet-flavoured stack (1x1-heavy: cuConv's best region)."""
    return SimpleCNN([
        (3, 3, 64, 2),
        (1, 1, 16, 1), (1, 1, 64, 1), (3, 3, 64, 1),
        (1, 1, 32, 1), (1, 1, 128, 1), (3, 3, 128, 1),
        (1, 1, 48, 1), (1, 1, 192, 1), (3, 3, 192, 1),
    ])


def tiny_cnn(num_classes: int = 3):
    """The deliberately tiny two-conv stack the multi-device smoke
    deployment serves (configs/serve.py DIST_SMOKE): per-image compute
    small enough that CPU-CI scaling runs are dominated by the fixed
    per-batch scheduling cost the device-count-aware buckets amortize —
    the same model tests/benchmarks share so the scaling and bitwise
    records describe one named deployment."""
    return SimpleCNN([(3, 3, 6, 2), (1, 1, 4, 1)],
                     num_classes=num_classes)


def resnet_like(num_classes: int = 10, image_shape=(32, 32, 3),
                precision=None):
    """Small ResNet-flavoured network: stem, maxpool, an identity
    residual block, a downsampling residual block with 1x1 projection,
    GAP + dense head — all inside ONE planned program.

    Each residual branch's last conv plans epilogue ``bias`` (no ReLU);
    the post-add ReLU lives on the ``add`` node, as in the real network.
    """
    def build(in_shape, dtype):
        b = GraphBuilder(in_shape, dtype)
        y = b.conv("stem", "input", 3, 16)
        y = b.pool("pool", y, kind="max", window=2)
        # identity block
        z = b.conv("b1c1", y, 3, 16)
        z = b.conv("b1c2", z, 3, 16, epilogue="bias")
        y = b.add("b1add", (y, z), activation="relu")
        # downsampling block with projection shortcut
        z = b.conv("b2c1", y, 3, 32, stride=2)
        z = b.conv("b2c2", z, 3, 32, epilogue="bias")
        p = b.conv("b2proj", y, 1, 32, stride=2, epilogue="bias")
        y = b.add("b2add", (p, z), activation="relu")
        y = b.gap("gap", y)
        b.dense("head", y, num_classes)
        return b.graph()
    return GraphModel(build, image_shape, name="resnet_like",
                      precision=precision)


def mobilenet_like(num_classes: int = 10, image_shape=(32, 32, 3),
                   precision=None):
    """Small MobileNet-flavoured network: strided stem, two depthwise-
    separable stages (3x3 depthwise conv with groups=C, then 1x1
    pointwise), GAP + dense head — all inside ONE planned program."""
    def build(in_shape, dtype):
        b = GraphBuilder(in_shape, dtype)
        y = b.conv("stem", "input", 3, 16, stride=2)
        y = b.conv("dw1", y, 3, 16, groups=16)
        y = b.conv("pw1", y, 1, 32)
        y = b.conv("dw2", y, 3, 32, stride=2, groups=32)
        y = b.conv("pw2", y, 1, 64)
        y = b.gap("gap", y)
        b.dense("head", y, num_classes)
        return b.graph()
    return GraphModel(build, image_shape, name="mobilenet_like",
                      precision=precision)


def fire_like(num_classes: int = 10, image_shape=(32, 32, 3),
              precision=None):
    """SqueezeNet fire module done properly: squeeze 1x1 feeding 1x1 and
    3x3 expand branches whose outputs CONCAT on the channel axis —
    planned as one program (the chain API could not express this)."""
    def build(in_shape, dtype):
        b = GraphBuilder(in_shape, dtype)
        y = b.conv("stem", "input", 3, 16, stride=2)
        s = b.conv("squeeze", y, 1, 8)
        e1 = b.conv("expand1", s, 1, 16)
        e3 = b.conv("expand3", s, 3, 16)
        y = b.concat("cat", (e1, e3))
        y = b.pool("pool", y, kind="avg", window=2)
        y = b.gap("gap", y)
        b.dense("head", y, num_classes)
        return b.graph()
    return GraphModel(build, image_shape, name="fire_like",
                      precision=precision)
