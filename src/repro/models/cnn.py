"""CNN inference graphs over the cuConv core (the paper's own domain).

The paper evaluates standalone convolution configurations drawn from five
CNNs; this module provides (a) a runnable sequential CNN for the
end-to-end inference example and (b) per-layer conv execution with the
cuDNN-style per-layer algorithm selection the paper's deployment story
relies on.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cuconv


def init_conv(key, kh, kw, c_in, c_out, dtype=jnp.float32):
    scale = 1.0 / np.sqrt(kh * kw * c_in)
    k1, k2 = jax.random.split(key)
    return {
        "w": jax.random.normal(k1, (kh, kw, c_in, c_out), dtype) * scale,
        "b": jnp.zeros((c_out,), dtype),
    }


def conv_block(p, x, stride=1, padding="same", algorithm="auto"):
    # bias+ReLU ride the conv as a planned epilogue: fused in VMEM on the
    # Pallas path, plain XLA ops elsewhere — never a separate HBM pass
    # materialized by this layer (DESIGN.md §4)
    return cuconv.conv2d(x, p["w"], stride, padding, algorithm,
                         bias=p["b"], activation="relu")


def maxpool(x, k=2, s=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, s, s, 1), "VALID")


class SimpleCNN:
    """Sequential conv stack + GAP head; spec: [(kh, kw, c_out, stride), ...]."""

    def __init__(self, spec: Sequence[Tuple[int, int, int, int]],
                 num_classes: int = 10, in_channels: int = 3):
        self.spec, self.num_classes, self.in_channels = (
            tuple(spec), num_classes, in_channels)

    def init(self, key):
        params: List = []
        c = self.in_channels
        keys = jax.random.split(key, len(self.spec) + 1)
        for i, (kh, kw, co, s) in enumerate(self.spec):
            params.append(init_conv(keys[i], kh, kw, c, co))
            c = co
        head = (jax.random.normal(keys[-1], (c, self.num_classes), jnp.float32)
                / np.sqrt(c))
        return {"convs": params, "head": head}

    def apply(self, params, x, algorithm="auto"):
        for p, (kh, kw, co, s) in zip(params["convs"], self.spec):
            x = conv_block(p, x, stride=s, algorithm=algorithm)
        x = x.mean(axis=(1, 2))                       # global average pool
        return x @ params["head"]


def squeezenet_like():
    """Small SqueezeNet-flavoured stack (1x1-heavy: cuConv's best region)."""
    return SimpleCNN([
        (3, 3, 64, 2),
        (1, 1, 16, 1), (1, 1, 64, 1), (3, 3, 64, 1),
        (1, 1, 32, 1), (1, 1, 128, 1), (3, 3, 128, 1),
        (1, 1, 48, 1), (1, 1, 192, 1), (3, 3, 192, 1),
    ])
