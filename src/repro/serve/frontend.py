"""Async serving front end: continuous batching, deadline-aware
admission, double-buffered dispatch, multi-resolution routing.

``CnnServeEngine.run()`` is a synchronous drain: every request waits for
the whole queue, host→device transfer serializes with compute, and one
engine serves exactly one image geometry.  ``AsyncServeFrontend`` keeps
the same compiled surface — jitted whole-network bucket programs built
by the shared ``BucketPrograms`` component (serve/cnn.py) — but puts a
scheduler in front of them:

* **Continuous batching.**  Batches close on a *bucket-full or
  ``max_wait_ms``* policy instead of a full drain: a full largest
  bucket dispatches immediately; a short tail dispatches (zero-padded)
  once its oldest request has waited ``max_wait_ms``.  ``poll()`` is the
  streaming entry point (dispatch what the policy allows, never force);
  ``run()`` drains.

* **Deadline-aware admission.**  Requests carry an optional
  ``deadline_ms`` (relative to submit; ``default_deadline_ms`` supplies
  the SLO for requests that don't say).  Within a geometry, admission
  is earliest-deadline-first; a request whose deadline has already
  passed at admission time is rejected with a typed
  ``DeadlineExceeded`` result (``status="deadline_exceeded"``, the
  error naming its lateness) instead of silently served.  A request
  with units already in flight is committed and always completes.

* **Double-buffered dispatch.**  Dispatch is asynchronous: the batch is
  packed on host, ``jax.device_put`` moves it, the program is launched
  without blocking, and the result is harvested (``block_until_ready``)
  only when the pipeline is ``pipeline_depth`` deep or at drain end.
  In steady state batch N+1's host packing + transfer overlaps batch
  N's in-flight compute — every such batch is flagged ``overlapped`` in
  telemetry, the signal the CI smoke test asserts on.

* **SLO-aware bucket choice.**  When the tightest pending deadline has
  less slack than the close policy's remaining wait (plus
  ``slo_close_margin_ms`` headroom), the batch closes immediately into
  the best-fitting — possibly padded, smaller — bucket instead of
  waiting for a larger one to fill (``stats()["slo_closes"]``).

* **Multi-resolution serving.**  One frontend owns several
  ``(image_shape, buckets)`` programs and routes each request to its
  geometry's bucket set — the one-shape-per-engine restriction is gone.

* **Sharded programs.**  ``mesh=`` (see serve/distributed.py) shards
  every bucket program's batch axis over a 1-D device mesh: configured
  buckets become per-shard capacities, params replicate once, and
  per-batch ``shard_units`` telemetry feeds the per-device
  utilization/imbalance rollups.

* **Telemetry.**  Every request leaves queue/transfer/compute/total
  latency (serve/telemetry.py); ``stats()`` exposes p50/p95/p99
  rollups, deadline misses, and overlap counters, and
  ``benchmarks/graph_serve.py`` writes them into
  ``BENCH_graph_serve.json``.

The scheduler is single-threaded and clock-injected (``clock=``): JAX's
async dispatch provides the device-side concurrency, so behaviour is
deterministic and testable with a fake clock.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.cnn import BucketPrograms, ImageRequest, scatter_outputs
from repro.serve.telemetry import BatchTrace, RequestTrace, Telemetry

#: request lifecycle states
PENDING = "pending"
SERVED = "served"
DEADLINE_EXCEEDED = "deadline_exceeded"


@dataclasses.dataclass
class DeadlineExceeded:
    """Typed rejection result: the request missed its deadline before
    admission.  ``lateness_ms`` is how far past the deadline admission
    found it."""
    rid: int
    deadline_ms: float
    lateness_ms: float

    def __str__(self):
        return (f"request {self.rid} deadline exceeded: "
                f"{self.lateness_ms:.1f}ms past its "
                f"{self.deadline_ms:.1f}ms deadline")


@dataclasses.dataclass
class ServeRequest(ImageRequest):
    """An ``ImageRequest`` with an optional latency SLO.

    ``deadline_ms`` is relative to submit time; ``None`` defers to the
    frontend's ``default_deadline_ms`` (and if that is also None the
    request never expires).  After serving, ``status`` is ``"served"``
    (outputs in ``out``) or ``"deadline_exceeded"`` (``error`` carries
    the typed ``DeadlineExceeded``; ``out`` stays None).
    """
    deadline_ms: Optional[float] = None
    status: str = PENDING
    error: Optional[DeadlineExceeded] = None
    # -- frontend-internal accounting (stamped at submit/dispatch) -----
    _submit_t: float = 0.0
    _deadline_t: Optional[float] = None     # absolute, frontend clock
    _seq: int = -1
    _first_dispatch_t: Optional[float] = None
    _transfer_ms: float = 0.0
    _compute_ms: float = 0.0
    _served_units: int = 0


@dataclasses.dataclass
class _InFlight:
    """One dispatched, not-yet-harvested batch."""
    shape: Tuple[int, int, int]
    chunk: List[Tuple[ServeRequest, int]]
    result: object                          # the async device array
    trace: BatchTrace


def _geom(shape: Sequence[int]) -> str:
    return "x".join(str(int(s)) for s in shape)


class AsyncServeFrontend:
    """Continuous-batching front end over shared bucket programs.

    ``geometries`` maps each served ``(H, W, C)`` image shape to its
    bucket tuple, e.g. ``{(32, 32, 3): (1, 4), (16, 16, 3): (1, 2)}`` —
    one frontend, several resolutions, each with its own
    ``BucketPrograms``.  Planning/precision/fusion knobs match
    ``CnnServeEngine`` and apply to every geometry.
    """

    def __init__(self, model, params,
                 geometries: Mapping[Tuple[int, int, int],
                                     Tuple[int, ...]], *,
                 max_wait_ms: float = 2.0,
                 default_deadline_ms: Optional[float] = None,
                 slo_close_margin_ms: float = 0.0,
                 pipeline_depth: int = 2, algorithm="auto",
                 backend: Optional[str] = None, precision=None,
                 fuse: bool = True, input_dtype=None, mesh=None,
                 clock: Callable[[], float] = time.perf_counter):
        if not geometries:
            raise ValueError("geometries must map at least one "
                             "(H, W, C) shape to a bucket tuple")
        if pipeline_depth < 1:
            raise ValueError(f"pipeline_depth must be >= 1; "
                             f"got {pipeline_depth}")
        # mesh= shards every geometry's bucket programs data-parallel
        # over a 1-D serve mesh: configured buckets become per-shard
        # capacities, params replicate once (see BucketPrograms /
        # serve/distributed.py)
        self.programs: Dict[Tuple[int, int, int], BucketPrograms] = {}
        for shape, buckets in dict(geometries).items():
            shape = tuple(map(int, shape))
            self.programs[shape] = BucketPrograms(
                model, params, shape, buckets=buckets,
                algorithm=algorithm, backend=backend, precision=precision,
                fuse=fuse, input_dtype=input_dtype, mesh=mesh)
        self.model, self.params = model, params
        self.mesh = mesh
        self.max_wait_ms = float(max_wait_ms)
        self.default_deadline_ms = default_deadline_ms
        self.slo_close_margin_ms = float(slo_close_margin_ms)
        self.pipeline_depth = int(pipeline_depth)
        self.telemetry = Telemetry()
        self._clock = clock
        self._pending: Dict[Tuple[int, int, int],
                            List[Tuple[ServeRequest, int]]] = {
            shape: [] for shape in self.programs}
        self._inflight: collections.deque = collections.deque()
        self._completed: List[ServeRequest] = []
        self._seq = 0
        self._max_inflight = 0
        self._slo_closes = 0
        self._batch_counts: Dict[str, int] = {}

    # ------------------------------------------------------------------
    @property
    def geometries(self) -> Tuple[Tuple[int, int, int], ...]:
        return tuple(self.programs)

    def warmup(self, *, measure: bool = False, tune: Optional[str] = None
               ) -> Dict[str, Dict[int, float]]:
        """Compile every geometry's bucket programs; per-bucket compile
        milliseconds keyed by geometry string."""
        return {_geom(shape): progs.warmup(measure=measure, tune=tune)
                for shape, progs in self.programs.items()}

    # -- admission ------------------------------------------------------
    def submit(self, req: ServeRequest) -> None:
        """Route a request to its geometry's pending queue."""
        shape = tuple(req.images.shape[1:])
        if shape not in self.programs:
            raise ValueError(
                f"request {req.rid}: image shape {shape} matches no "
                f"served geometry {[_geom(s) for s in self.programs]}")
        now = self._clock()
        req._submit_t = now
        req._seq, self._seq = self._seq, self._seq + 1
        deadline = (req.deadline_ms if req.deadline_ms is not None
                    else self.default_deadline_ms)
        req._deadline_t = (None if deadline is None
                           else now + float(deadline) / 1e3)
        self._pending[shape].extend(
            (req, i) for i in range(req.images.shape[0]))

    def _reject(self, req: ServeRequest, now: float) -> None:
        deadline_ms = (req._deadline_t - req._submit_t) * 1e3
        lateness_ms = (now - req._deadline_t) * 1e3
        req.status = DEADLINE_EXCEEDED
        req.error = DeadlineExceeded(req.rid, deadline_ms, lateness_ms)
        req.done = True
        queue_ms = (now - req._submit_t) * 1e3
        self.telemetry.record_request(RequestTrace(
            rid=req.rid, geometry=_geom(req.images.shape[1:]),
            images=int(req.images.shape[0]), status=DEADLINE_EXCEEDED,
            deadline_ms=deadline_ms, queue_ms=queue_ms, transfer_ms=0.0,
            compute_ms=0.0, total_ms=queue_ms))
        self._completed.append(req)

    def _purge_expired(self, shape, now: float) -> None:
        """Deadline-aware admission: requests already past their
        deadline are rejected with a typed result.  Requests with units
        in flight are committed and never purged."""
        pend = self._pending[shape]
        expired = {id(r) for r, _ in pend
                   if r._deadline_t is not None and now > r._deadline_t
                   and r._first_dispatch_t is None}
        if not expired:
            return
        self._pending[shape] = [(r, i) for r, i in pend
                                if id(r) not in expired]
        rejected = {id(r): r for r, _ in pend if id(r) in expired}
        for r in rejected.values():
            self._reject(r, now)

    # -- scheduling -----------------------------------------------------
    def _form_batch(self, shape, now: float, *, force: bool
                    ) -> Optional[Tuple[List, int]]:
        """EDF-order the geometry's pending units and close a batch if
        the policy allows: largest bucket full → dispatch now; else
        dispatch the best-fitting bucket once the oldest pending request
        has waited ``max_wait_ms`` (or unconditionally when draining).

        **SLO-aware close**: when the tightest pending deadline has less
        slack than the wait the close policy would still impose (plus
        ``slo_close_margin_ms`` of service headroom), the batch closes
        NOW into the best-fitting — possibly padded, smaller — bucket
        instead of waiting for a larger one to fill.  A lone
        tight-deadline request is served padded rather than expiring in
        the queue it was asked to wait in."""
        self._purge_expired(shape, now)
        pend = self._pending[shape]
        if not pend:
            return None
        pend.sort(key=lambda u: (
            u[0]._deadline_t if u[0]._deadline_t is not None
            else float("inf"), u[0]._seq, u[1]))
        progs = self.programs[shape]
        bmax = progs.buckets[-1]
        if len(pend) < bmax:
            oldest_wait_ms = (now - min(r._submit_t for r, _ in pend)) * 1e3
            if not force and oldest_wait_ms < self.max_wait_ms:
                remaining_wait_ms = self.max_wait_ms - oldest_wait_ms
                slacks = [(r._deadline_t - now) * 1e3 for r, _ in pend
                          if r._deadline_t is not None]
                tight = min(slacks) if slacks else None
                if (tight is None
                        or tight > remaining_wait_ms
                        + self.slo_close_margin_ms):
                    return None
                self._slo_closes += 1
        b = progs.pick_bucket(len(pend))
        chunk, self._pending[shape] = pend[:b], pend[b:]
        return chunk, b

    def _dispatch(self, shape, chunk, bucket: int) -> None:
        progs = self.programs[shape]
        xb = progs.pack(chunk, bucket)
        # transfer: host blocks only on the COPY — any in-flight batch
        # keeps computing on the device meanwhile (the overlap).  The
        # put is explicit (and sharded under a mesh); params ride the
        # program's own once-replicated tree, never re-transferred.
        overlapped = bool(self._inflight)
        t0 = self._clock()
        xd = progs.put(xb)
        jax.block_until_ready(xd)
        t1 = self._clock()
        y = progs.fn(bucket)(progs.params, xd)  # async dispatch: no block
        td = self._clock()
        trace = BatchTrace(
            geometry=_geom(shape), bucket=bucket, units=len(chunk),
            padded=bucket - len(chunk), transfer_t0=t0, transfer_t1=t1,
            dispatch_t=td, overlapped=overlapped,
            shard_units=progs.shard_units(len(chunk), bucket),
            dtype=progs.serve_dtype(bucket))
        for r, _ in chunk:
            if r._first_dispatch_t is None:
                r._first_dispatch_t = t0
        self._inflight.append(_InFlight(shape, list(chunk), y, trace))
        self._max_inflight = max(self._max_inflight, len(self._inflight))
        key = f"{_geom(shape)}/b{bucket}"
        self._batch_counts[key] = self._batch_counts.get(key, 0) + 1

    def _harvest_one(self) -> None:
        fl = self._inflight.popleft()
        # device_get is an EXPLICIT device->host gather (sharded outputs
        # reassemble across the mesh), keeping a warm serve loop clean
        # under jax.transfer_guard("disallow")
        y = np.asarray(jax.device_get(jax.block_until_ready(fl.result)))
        now = self._clock()
        fl.trace.harvest_t = now
        self.telemetry.record_batch(fl.trace)
        scatter_outputs(fl.chunk, y)
        seen: Dict[int, ServeRequest] = {}
        counts: Dict[int, int] = {}
        for r, _ in fl.chunk:
            seen[id(r)] = r
            counts[id(r)] = counts.get(id(r), 0) + 1
        for rid_, r in seen.items():
            r._transfer_ms += fl.trace.transfer_ms
            r._compute_ms += fl.trace.compute_ms
            r._served_units += counts[rid_]
            if r._served_units == r.images.shape[0]:
                self._complete(r, now)

    def _complete(self, req: ServeRequest, now: float) -> None:
        req.status = SERVED
        req.done = True
        deadline_ms = (None if req._deadline_t is None else
                       (req._deadline_t - req._submit_t) * 1e3)
        self.telemetry.record_request(RequestTrace(
            rid=req.rid, geometry=_geom(req.images.shape[1:]),
            images=int(req.images.shape[0]), status=SERVED,
            deadline_ms=deadline_ms,
            queue_ms=(req._first_dispatch_t - req._submit_t) * 1e3,
            transfer_ms=req._transfer_ms, compute_ms=req._compute_ms,
            total_ms=(now - req._submit_t) * 1e3))
        self._completed.append(req)

    # -- serving entry points -------------------------------------------
    def poll(self) -> List[ServeRequest]:
        """One scheduler pass: dispatch every batch the close policy
        allows, harvesting only when the pipeline is full.  Returns the
        requests that COMPLETED during this pass (served or rejected);
        work still in flight completes on a later ``poll``/``flush``."""
        start = len(self._completed)
        for shape in self.programs:
            while True:
                batch = self._form_batch(shape, self._clock(), force=False)
                if batch is None:
                    break
                self._dispatch(shape, *batch)
                while len(self._inflight) >= self.pipeline_depth:
                    self._harvest_one()
        return self._completed[start:]

    def flush(self) -> List[ServeRequest]:
        """Harvest every in-flight batch; returns newly completed."""
        start = len(self._completed)
        while self._inflight:
            self._harvest_one()
        return self._completed[start:]

    def run(self) -> List[ServeRequest]:
        """Drain everything pending (the ``CnnServeEngine.run``-shaped
        entry point): batches close regardless of ``max_wait_ms``, the
        pipeline stays ``pipeline_depth`` deep, and every submitted
        request comes back completed — served or deadline-rejected — in
        completion order."""
        start = len(self._completed)
        while any(self._pending.values()):
            for shape in self.programs:
                while True:
                    batch = self._form_batch(shape, self._clock(),
                                             force=True)
                    if batch is None:
                        break
                    self._dispatch(shape, *batch)
                    while len(self._inflight) >= self.pipeline_depth:
                        self._harvest_one()
        self.flush()
        return self._completed[start:]

    # -- observability ---------------------------------------------------
    def pending_counts(self) -> Dict[str, int]:
        return {_geom(s): len(u) for s, u in self._pending.items() if u}

    def stats(self) -> Dict:
        """JSON-ready serving summary: request/batch counters, deadline
        misses, double-buffer overlap counters, and p50/p95/p99 latency
        rollups per stage (queue/transfer/compute/total)."""
        st = self.telemetry.rollup()
        served = [t for t in self.telemetry.requests
                  if t.status == SERVED]
        st.update({
            "geometries": [_geom(s) for s in self.programs],
            "batches_by_program": dict(sorted(self._batch_counts.items())),
            # serving dtype per BUILT bucket program ("int8" /
            # "float32+int8" under a QuantPolicy) — unbuilt buckets are
            # omitted rather than force-planned here
            "serve_dtype_by_program": {
                f"{_geom(shape)}/b{b}": progs.serve_dtype(b)
                for shape, progs in self.programs.items()
                for b in progs.compiled_buckets},
            "pending": self.pending_counts(),
            "inflight": len(self._inflight),
            "max_inflight": self._max_inflight,
            # batches closed early because a pending deadline was
            # tighter than the remaining close-policy wait
            "slo_closes": self._slo_closes,
            # served past their deadline (admitted on time, finished
            # late) — distinct from admission-rejected deadline_misses
            "late_served": sum(
                1 for t in served
                if t.deadline_ms is not None and t.total_ms > t.deadline_ms),
        })
        return st
