"""Multi-device sharded serving: a per-host dispatcher over sharded
bucket programs.

The single-device serving stack multiplexes traffic onto jitted bucket
programs (serve/cnn.py) behind a continuous-batching scheduler
(serve/frontend.py).  This module scales that stack across *devices*
and *hosts* without changing what a bucket program is:

* **Sharded bucket programs.**  ``ShardedServeDispatcher`` builds its
  ``AsyncServeFrontend`` with a 1-D ``('data',)`` serve mesh
  (launch/mesh.make_serve_mesh), so every bucket program is the
  per-shard-geometry ``GraphPlan`` — tuned launch configs from
  autotune.json reused per shard unchanged — wrapped in ``shard_map``
  and jitted with the batch axis sharded.  Configured buckets are
  per-shard capacities; served (global) buckets are
  ``bucket × mesh_size``, device-count-aware by construction.  Because
  the per-shard body traces at the per-shard batch shape, outputs are
  bitwise-identical to the single-device engine at that bucket.

* **One param replication.**  ``dist.sharding.replicate_params`` moves
  the param tree onto the mesh exactly once (explicit ``device_put``
  with a replicated ``NamedSharding``); every geometry's programs share
  the replicated tree by reference and a warm serve loop runs clean
  under ``jax.transfer_guard("disallow")``.

* **Logical engine partitions.**  The dispatcher exposes one logical
  partition per mesh device: ``partitions()`` reports each device's
  real-image count and slot utilization (padding concentrates in the
  trailing shards), and ``stats()["sharding"]`` carries the
  shard-imbalance counters rolled up in serve/telemetry.py.

* **Scale-out seam.**  Admission is ``process_index``-disciplined: a
  multi-process deployment runs ONE dispatcher per host, and
  ``owned_geometries`` deterministically partitions the geometry table
  across processes (sorted round-robin) so every request geometry has
  exactly one owner — turning multi-host serving into a config change
  (launch/serve.py ``--cnn-dist``), in the spirit of the actor/learner
  split the ROADMAP cites.

On CPU CI the whole subsystem runs under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` forced host
devices: throughput in BENCH_graph_serve.json scales near-linearly with
the device count because the global buckets grow with the mesh while
the per-batch scheduling cost does not (benchmarks/loadgen.py writes
the ``sharded_scaling`` record).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Mapping, Optional, Tuple

import jax

from repro.dist.sharding import replicate_params
from repro.launch.mesh import make_serve_mesh
from repro.serve.frontend import AsyncServeFrontend, ServeRequest


def owned_geometries(geometries: Mapping[Tuple[int, int, int],
                                         Tuple[int, ...]],
                     process_index: int, process_count: int
                     ) -> Dict[Tuple[int, int, int], Tuple[int, ...]]:
    """Deterministic per-host ownership of the geometry table.

    Geometries are sorted and dealt round-robin, so every process
    derives the same partition from the same config with no
    coordination, every geometry has exactly one owner, and adding a
    host is a config change.  A process may own nothing (more hosts
    than geometries) — its dispatcher idles.
    """
    if not 0 <= process_index < process_count:
        raise ValueError(f"process_index {process_index} not in "
                         f"[0, {process_count})")
    items = sorted((tuple(map(int, s)), tuple(b))
                   for s, b in dict(geometries).items())
    return {shape: buckets for i, (shape, buckets) in enumerate(items)
            if i % process_count == process_index}


class ShardedServeDispatcher:
    """Per-host dispatcher: sharded bucket programs behind the async
    scheduler.

    Reuses ``AsyncServeFrontend``'s admission/EDF/SLO/telemetry
    machinery wholesale — the dispatcher owns the mesh, the one-time
    param replication, the host's geometry ownership, and the
    per-device accounting on top.  ``mesh=None`` forms the serve mesh
    over every addressable device (1 device ⇒ behaves exactly like the
    plain frontend, same scheduler states).
    """

    def __init__(self, model, params,
                 geometries: Mapping[Tuple[int, int, int],
                                     Tuple[int, ...]], *,
                 mesh=None, process_index: Optional[int] = None,
                 process_count: Optional[int] = None,
                 max_wait_ms: float = 2.0,
                 default_deadline_ms: Optional[float] = None,
                 slo_close_margin_ms: float = 0.0,
                 pipeline_depth: int = 2, algorithm="auto",
                 backend: Optional[str] = None, precision=None,
                 fuse: bool = True, input_dtype=None,
                 clock: Callable[[], float] = time.perf_counter):
        self.mesh = mesh if mesh is not None else make_serve_mesh()
        self.n_devices = int(self.mesh.devices.size)
        self.process_index = (jax.process_index() if process_index is None
                              else int(process_index))
        self.process_count = (jax.process_count() if process_count is None
                              else int(process_count))
        self.owned = owned_geometries(geometries, self.process_index,
                                      self.process_count)
        # ONE explicit replication; every geometry's BucketPrograms sees
        # already-replicated leaves and passes them through untouched
        self.params = replicate_params(params, self.mesh)
        self.model = model
        self.frontend: Optional[AsyncServeFrontend] = None
        if self.owned:
            self.frontend = AsyncServeFrontend(
                model, self.params, self.owned,
                max_wait_ms=max_wait_ms,
                default_deadline_ms=default_deadline_ms,
                slo_close_margin_ms=slo_close_margin_ms,
                pipeline_depth=pipeline_depth, algorithm=algorithm,
                backend=backend, precision=precision, fuse=fuse,
                input_dtype=input_dtype, mesh=self.mesh, clock=clock)

    # ------------------------------------------------------------------
    @property
    def geometries(self) -> Tuple[Tuple[int, int, int], ...]:
        """The geometries THIS host owns (its admission surface)."""
        return tuple(self.owned)

    def global_buckets(self, shape) -> Tuple[int, ...]:
        """The device-count-aware (global) bucket sizes serving one
        owned geometry — per-shard config × mesh size."""
        return self.frontend.programs[tuple(map(int, shape))].buckets

    def warmup(self, *, measure: bool = False,
               tune: Optional[str] = None) -> Dict[str, Dict[int, float]]:
        if self.frontend is None:
            return {}
        return self.frontend.warmup(measure=measure, tune=tune)

    # -- serving entry points (the frontend's, ownership-checked) -------
    def submit(self, req: ServeRequest) -> None:
        """Admit a request this host owns.  A geometry owned by a
        different process is a routing error, named as such — the
        deterministic ownership rule means the caller can compute the
        right host without asking anyone."""
        if self.frontend is not None:
            shape = tuple(req.images.shape[1:])
            if shape in self.owned:
                return self.frontend.submit(req)
        raise ValueError(
            f"request {req.rid}: geometry {tuple(req.images.shape[1:])} "
            f"is not owned by process {self.process_index}/"
            f"{self.process_count} (owned: {list(self.owned)})")

    def poll(self) -> List[ServeRequest]:
        return [] if self.frontend is None else self.frontend.poll()

    def flush(self) -> List[ServeRequest]:
        return [] if self.frontend is None else self.frontend.flush()

    def run(self) -> List[ServeRequest]:
        return [] if self.frontend is None else self.frontend.run()

    # -- observability ---------------------------------------------------
    def partitions(self) -> List[Dict]:
        """One logical engine partition per mesh device: which device,
        how many real images it computed, and its slot utilization."""
        shard = (self.frontend.telemetry.shard_rollup()
                 if self.frontend is not None else None)
        out = []
        for i, dev in enumerate(self.mesh.devices.flat):
            units = shard["per_device_units"][i] if shard else 0
            util = shard["per_device_utilization"][i] if shard else 0.0
            out.append({"partition": i, "device": str(dev),
                        "units": units, "utilization": util})
        return out

    def stats(self) -> Dict:
        """The frontend's JSON-ready rollup plus the mesh/ownership
        view: device count, per-partition utilization, shard-imbalance
        counters, and this host's slice of the deployment."""
        st = self.frontend.stats() if self.frontend is not None else {
            "requests": 0, "served": 0, "geometries": []}
        st.update({
            "process_index": self.process_index,
            "process_count": self.process_count,
            "devices": self.n_devices,
            "partitions": self.partitions(),
            "global_buckets": {
                "x".join(map(str, s)): list(self.global_buckets(s))
                for s in self.owned},
        })
        return st
