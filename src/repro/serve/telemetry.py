"""Per-request serving telemetry: latency stages and percentile rollups.

Every request served by the async front end (serve/frontend.py) leaves a
``RequestTrace`` — how long it queued, how long its batches spent in
host→device transfer, how long the device computed, and the wall total —
and every dispatched batch leaves a ``BatchTrace`` (geometry, bucket,
padding, the transfer/dispatch/harvest timeline, and whether its
transfer overlapped an in-flight batch — the double-buffering signal).
``Telemetry.rollup()`` turns the traces into the machine-readable
summary ``frontend.stats()`` exposes and ``BENCH_graph_serve.json``
records: p50/p95/p99 per stage, deadline-miss counts, overlap counters.

The module is deliberately model-free: it never imports jax and knows
nothing about programs or plans, so any serving layer can record into
it.  All times are seconds from one injected monotonic clock; rollups
convert to milliseconds.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

#: the latency stages every request is accounted under (ms in rollups)
STAGES = ("queue", "transfer", "compute", "total")


def percentile(xs: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile (numpy's default method), monotone
    in ``q`` by construction — so p99 >= p95 >= p50 always holds."""
    if not xs:
        raise ValueError("percentile of empty sequence")
    s = sorted(float(x) for x in xs)
    if len(s) == 1:
        return s[0]
    pos = (q / 100.0) * (len(s) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(s) - 1)
    frac = pos - lo
    return s[lo] * (1.0 - frac) + s[hi] * frac


def rollup_percentiles(xs: Sequence[float],
                       qs: Sequence[float] = (50, 95, 99)) -> Dict[str, float]:
    """``{"p50": ..., "p95": ..., "p99": ...}`` for one latency series."""
    return {f"p{int(q)}": percentile(xs, q) for q in qs}


@dataclasses.dataclass
class RequestTrace:
    """One served (or rejected) request's latency accounting.

    ``transfer_ms``/``compute_ms`` sum over every batch that carried one
    of the request's images — a request larger than the biggest bucket
    experiences several transfer/compute windows and is charged all of
    them.  ``compute_ms`` is the in-flight window (dispatch → observed
    completion): with double buffering it may include time queued behind
    the previous batch on the device, which is exactly what the request
    experienced.
    """
    rid: int
    geometry: str                       # "HxWxC"
    images: int
    status: str                         # "served" | "deadline_exceeded"
    deadline_ms: Optional[float]
    queue_ms: float
    transfer_ms: float
    compute_ms: float
    total_ms: float

    def stage_ms(self, stage: str) -> float:
        return getattr(self, f"{stage}_ms")


@dataclasses.dataclass
class BatchTrace:
    """One dispatched batch's timeline (all times: seconds on the
    frontend's clock).  ``overlapped`` is True when this batch's
    host→device transfer started while a previous batch was still in
    flight on the device — the double-buffering overlap signal the CI
    smoke test asserts on.  ``shard_units`` (sharded serving only) is
    how many REAL images landed on each mesh device — batch padding
    concentrates in the trailing shards, so ``max - min`` per batch is
    the shard-imbalance signal ``rollup()`` counts.  ``dtype`` is the
    serving dtype of the bucket program that ran the batch (e.g.
    ``"float32"``, ``"bfloat16"``, ``"float32+int8"`` for a quantized
    graph with fp fallback nodes) — stamped by the dispatcher, opaque
    here."""
    geometry: str
    bucket: int
    units: int                          # real (non-padded) images
    padded: int
    transfer_t0: float
    transfer_t1: float
    dispatch_t: float
    harvest_t: float = 0.0
    overlapped: bool = False
    shard_units: Optional[Sequence[int]] = None    # per-device real images
    dtype: Optional[str] = None         # bucket program's serving dtype

    @property
    def transfer_ms(self) -> float:
        return (self.transfer_t1 - self.transfer_t0) * 1e3

    @property
    def compute_ms(self) -> float:
        return (self.harvest_t - self.dispatch_t) * 1e3


class Telemetry:
    """Accumulates request/batch traces and rolls them up."""

    def __init__(self):
        self.requests: List[RequestTrace] = []
        self.batches: List[BatchTrace] = []
        self.deadline_misses = 0

    def record_request(self, trace: RequestTrace) -> None:
        self.requests.append(trace)
        if trace.status == "deadline_exceeded":
            self.deadline_misses += 1

    def record_batch(self, trace: BatchTrace) -> None:
        self.batches.append(trace)

    # ------------------------------------------------------------------
    def latency_ms(self) -> Dict[str, Dict[str, float]]:
        """p50/p95/p99 per stage over the *served* requests."""
        served = [t for t in self.requests if t.status == "served"]
        if not served:
            return {}
        return {stage: rollup_percentiles([t.stage_ms(stage)
                                           for t in served])
                for stage in STAGES}

    def shard_rollup(self) -> Optional[Dict]:
        """Per-device utilization + imbalance over the sharded batches.

        ``per_device_units`` counts real images landed per mesh device;
        ``per_device_utilization`` divides by that device's offered
        slots (its share of every dispatched bucket).  A batch is
        ``imbalanced`` when its real units don't divide evenly across
        the shards (padding rode the trailing devices); the max
        per-batch spread is reported so a pathological router shows up
        as a number, not a feeling.  None when nothing sharded ran.
        """
        sb = [b for b in self.batches if b.shard_units is not None]
        if not sb:
            return None
        n = max(len(b.shard_units) for b in sb)
        units = [0] * n
        slots = [0] * n
        for b in sb:
            per = b.bucket // len(b.shard_units)
            for i, u in enumerate(b.shard_units):
                units[i] += int(u)
                slots[i] += per
        spreads = [max(b.shard_units) - min(b.shard_units) for b in sb]
        return {
            "devices": n,
            "per_device_units": units,
            "per_device_utilization": [
                u / s if s else 0.0 for u, s in zip(units, slots)],
            "sharded_batches": len(sb),
            "imbalanced_batches": sum(1 for s in spreads if s > 0),
            "max_shard_imbalance": max(spreads),
        }

    def rollup(self) -> Dict:
        """The JSON-ready summary ``frontend.stats()`` builds on."""
        served = [t for t in self.requests if t.status == "served"]
        out = {
            "requests": len(self.requests),
            "served": len(served),
            "deadline_misses": self.deadline_misses,
            "images": sum(t.images for t in served),
            "batches": len(self.batches),
            "padded_slots": sum(b.padded for b in self.batches),
            "overlapped_batches": sum(1 for b in self.batches
                                      if b.overlapped),
            "latency_ms": self.latency_ms(),
        }
        dtypes = self.dtype_rollup()
        if dtypes:
            out["serve_dtypes"] = dtypes
        shard = self.shard_rollup()
        if shard is not None:
            out["sharding"] = shard
        return out

    def dtype_rollup(self) -> Dict[str, Dict[str, int]]:
        """Per serving-dtype batch/image counters over the dispatched
        batches — ``{"int8": {"batches": 3, "images": 12}, ...}``.
        Empty when no dispatcher stamped a dtype (older layers)."""
        out: Dict[str, Dict[str, int]] = {}
        for b in self.batches:
            if b.dtype is None:
                continue
            d = out.setdefault(b.dtype, {"batches": 0, "images": 0})
            d["batches"] += 1
            d["images"] += int(b.units)
        return out
