from repro.serve.engine import ServeEngine, Request  # noqa: F401
from repro.serve.cnn import (  # noqa: F401
    BucketPrograms, CnnServeEngine, ImageRequest)
from repro.serve.frontend import (  # noqa: F401
    AsyncServeFrontend, DeadlineExceeded, ServeRequest)
from repro.serve.distributed import (  # noqa: F401
    ShardedServeDispatcher, owned_geometries)
from repro.serve.telemetry import Telemetry  # noqa: F401
