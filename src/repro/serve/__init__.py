from repro.serve.engine import ServeEngine, Request  # noqa: F401
