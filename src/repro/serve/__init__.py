from repro.serve.engine import ServeEngine, Request  # noqa: F401
from repro.serve.cnn import CnnServeEngine, ImageRequest  # noqa: F401
