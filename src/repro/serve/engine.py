"""Batched inference engine: continuous-batching slots over a static cache.

The compiled surface is exactly two jitted functions (one prefill, one
decode step) over fixed shapes — the standard way to serve on TPU where
recompilation is the enemy.  Requests are multiplexed onto batch *slots*;
a slot holds one sequence's KV/SSM cache region.  Finished slots are
refilled from the queue (continuous batching).  Per-slot offsets are
tracked host-side; the decode step runs all active slots together.

Note on offsets: the cache is a rectangular (slots, max_len) region and
each slot may sit at a different length.  The decode step uses a vector
of per-slot offsets for masking and a shared write cursor per step by
aligning slots left (prompt lengths are padded to the same offset grid at
prefill time) — the classic static-shape compromise; a production paged
cache would replace this (documented in DESIGN.md future work).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (prompt_len,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, greedy: bool = True):
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        self.greedy = greedy
        self.cache = lm.init_cache(cfg, slots, max_len)
        self.offset = 0                   # shared left-aligned cursor
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []

        def prefill_fn(params, batch, cache):
            logits, new_cache = lm.prefill(params, cfg, batch, cache)
            return logits[:, -1, :], new_cache

        def decode_fn(params, batch, cache, offset):
            logits, new_cache = lm.decode_step(params, cfg, batch, cache,
                                               offset)
            return logits[:, -1, :], new_cache

        self._prefill = jax.jit(prefill_fn, donate_argnums=(2,))
        self._decode = jax.jit(decode_fn, donate_argnums=(2,))

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.out_tokens = []
        self.queue.append(req)

    def _fill_batch(self, prompts_len: int):
        """Left-align every slot at the same offset grid (static shapes)."""
        toks = np.zeros((self.slots, prompts_len), np.int32)
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                self.active[s] = self.queue.pop(0)
            r = self.active[s]
            if r is not None:
                p = r.prompt[:prompts_len]
                toks[s, prompts_len - len(p):] = p       # right-pack
        return jnp.asarray(toks)

    def run(self, prompt_len: int = 32) -> List[Request]:
        """Serve until queue and slots drain (wave-based batching):
        a wave of up to ``slots`` requests is prefilled together, decoded
        until every member finishes, then the next wave is admitted.
        Returns finished requests."""
        finished: List[Request] = []
        while self.queue or any(r is not None for r in self.active):
            if all(r is None for r in self.active):
                # admit the next wave; stale cache beyond `offset` is
                # masked by the causal offset logic, SSM states are
                # recomputed by prefill itself
                self.offset = 0
                toks = self._fill_batch(prompt_len)
                logits, self.cache = self._prefill(
                    self.params, {"tokens": toks}, self.cache)
                self.offset = prompt_len
                self._emit(self._sample(logits), finished)
                continue
            if self.offset >= self.max_len:
                # out of cache: finish everything still active
                for s, r in enumerate(self.active):
                    if r is not None:
                        r.done = True
                        finished.append(r)
                        self.active[s] = None
                continue
            step_toks = self._current_tokens()
            logits, self.cache = self._decode(
                self.params, {"tokens": step_toks}, self.cache,
                jnp.int32(self.offset))
            self.offset += 1
            self._emit(self._sample(logits), finished)
        return finished

    # ------------------------------------------------------------------
    def _current_tokens(self):
        toks = np.zeros((self.slots, 1), np.int32)
        for s, r in enumerate(self.active):
            if r is not None and r.out_tokens:
                toks[s, 0] = r.out_tokens[-1]
        return jnp.asarray(toks)

    def _sample(self, logits) -> np.ndarray:
        logits = np.asarray(logits[..., :self.cfg.vocab_size], np.float32)
        return logits.argmax(-1)

    def _emit(self, next_tok, finished):
        for s, r in enumerate(self.active):
            if r is None:
                continue
            r.out_tokens.append(int(next_tok[s]))
            if len(r.out_tokens) >= r.max_new_tokens:
                r.done = True
                finished.append(r)
                self.active[s] = None
