"""Batch-bucketed CNN serving over graph-planned programs.

The LM engine (serve/engine.py) keeps its compiled surface to two jitted
functions over fixed shapes; this engine applies the same discipline to
CNN inference traffic: the ONLY compiled programs are one jitted
whole-network GraphPlan execution per configured batch *bucket*.  Any
model exposing ``graph_plan``/``apply`` over the operator IR plugs in —
including the real network shapes (``resnet_like`` residual blocks,
``mobilenet_like`` depthwise stages, ``fire_like`` concats) whose whole
forward pass, head included, is one planned program.
Incoming image requests (each carrying one image or a small batch) are
flattened into per-image units and multiplexed onto the largest bucket
that fits the remaining queue — short remainders ride the smallest
bucket with zero-padded slots.  Plans are resolved once per bucket (and
persisted via the graph-level cache), so a warm engine serves any
request mix with zero plan() resolutions and at most ``len(buckets)``
compiled shapes.  A graph-wide ``PrecisionPolicy`` (``precision="bf16"``)
plans every bucket program in reduced precision end to end — fp32
master params, fp32 accumulation, precision-distinct cache keys.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ImageRequest:
    rid: int
    images: np.ndarray                  # (n, H, W, C), or (H, W, C) for one
    out: Optional[np.ndarray] = None    # (n, num_classes) once served
    done: bool = False

    def __post_init__(self):
        self.images = np.asarray(self.images)
        if self.images.ndim == 3:
            self.images = self.images[None]
        if self.images.ndim != 4:
            raise ValueError(f"images must be (n, H, W, C) or (H, W, C); "
                             f"got shape {self.images.shape}")


class CnnServeEngine:
    """Serve image-classification traffic through batch-bucketed plans."""

    def __init__(self, model, params, image_shape: Tuple[int, int, int], *,
                 buckets: Tuple[int, ...] = (1, 4, 8), algorithm="auto",
                 backend: Optional[str] = None, precision=None,
                 fuse: bool = True):
        self.model, self.params = model, params
        self.image_shape = tuple(map(int, image_shape))     # (H, W, C)
        self.buckets = tuple(sorted({int(b) for b in buckets}))
        if not self.buckets or self.buckets[0] < 1:
            raise ValueError(f"buckets must be positive ints; got {buckets}")
        self.algorithm = algorithm
        self.backend = backend or jax.default_backend()
        # graph-wide PrecisionPolicy (e.g. "bf16") for every bucket
        # program; None defers to the model's own policy / fp32 inputs.
        # Master params stay fp32 — conv nodes cast per their specs, so
        # the same engine params serve any policy.
        self.precision = precision
        # cross-layer fusion pass (on by default); fuse=False serves
        # every bucket's unfused program — the escape hatch mirrors
        # plan_graph's
        self.fuse = fuse
        self.queue: List[ImageRequest] = []
        self._fns: Dict[int, Callable] = {}    # bucket -> jitted program
        self.stats = {"images": 0, "padded_slots": 0,
                      "batches": {b: 0 for b in self.buckets}}

    # ------------------------------------------------------------------
    @property
    def compiled_buckets(self) -> Tuple[int, ...]:
        """Batch sizes with a built program — never exceeds ``buckets``."""
        return tuple(sorted(self._fns))

    def _bucket_fn(self, b: int) -> Callable:
        fn = self._fns.get(b)
        if fn is None:
            gp = self.model.graph_plan(
                (b,) + self.image_shape, backend=self.backend,
                force=None if self.algorithm == "auto" else self.algorithm,
                precision=self.precision, fuse=self.fuse)
            fn = jax.jit(lambda params, xb: self.model.apply(
                params, xb, graph_plan=gp))
            self._fns[b] = fn
        return fn

    def warmup(self, *, measure: bool = False,
               tune: Optional[str] = None) -> Dict[int, float]:
        """Resolve + compile every bucket program in one sweep.

        ``tune="algo"`` first measure-autotunes each bucket's graph
        (GraphPlan.warmup) and ``tune="full"`` also sweeps the winning
        executors' candidate launch configs, so the compiled programs
        embed the measured ``(algorithm, config)`` winners — a served
        graph is tuned once here and replayed from cache ever after.
        ``measure=True`` is the back-compat spelling of ``tune="algo"``.
        Returns per-bucket compile milliseconds.
        """
        if measure and tune is None:
            tune = "algo"
        H, W, C = self.image_shape
        out = {}
        for b in self.buckets:
            if tune is not None and self.algorithm == "auto":
                self.model.graph_plan((b, H, W, C), backend=self.backend,
                                      precision=self.precision,
                                      fuse=self.fuse) \
                    .warmup(tune=tune)
                # the measured sweep may have swapped node plans: an
                # already-compiled program would keep serving the stale
                # trace, so force a rebuild
                self._fns.pop(b, None)
            fn = self._bucket_fn(b)
            x = jnp.zeros((b, H, W, C), jnp.float32)
            t0 = time.perf_counter()
            fn(self.params, x).block_until_ready()
            out[b] = (time.perf_counter() - t0) * 1e3
        return out

    # ------------------------------------------------------------------
    def submit(self, req: ImageRequest) -> None:
        if tuple(req.images.shape[1:]) != self.image_shape:
            raise ValueError(f"request {req.rid}: image shape "
                             f"{req.images.shape[1:]} != engine shape "
                             f"{self.image_shape}")
        self.queue.append(req)

    def _pick_bucket(self, pending: int) -> int:
        fits = [b for b in self.buckets if b <= pending]
        return max(fits) if fits else self.buckets[0]

    def run(self) -> List[ImageRequest]:
        """Drain the queue; returns the served requests (outputs filled).

        Requests are flattened to per-image units and packed batch by
        batch: the largest bucket that the remaining unit count fills,
        else the smallest bucket with padded (zero) slots.
        """
        served, units = list(self.queue), []
        for r in served:
            units.extend((r, i) for i in range(r.images.shape[0]))
        cursor = 0
        while cursor < len(units):
            b = self._pick_bucket(len(units) - cursor)
            chunk = units[cursor:cursor + b]
            xb = np.zeros((b,) + self.image_shape, np.float32)
            for j, (r, i) in enumerate(chunk):
                xb[j] = r.images[i]
            y = np.asarray(self._bucket_fn(b)(self.params, jnp.asarray(xb)))
            for j, (r, i) in enumerate(chunk):
                if r.out is None:
                    r.out = np.zeros((r.images.shape[0], y.shape[-1]),
                                     y.dtype)
                r.out[i] = y[j]
            self.stats["batches"][b] += 1
            self.stats["padded_slots"] += b - len(chunk)
            self.stats["images"] += len(chunk)
            cursor += b
        # only a fully drained queue is cleared: a failure above leaves
        # every request submitted (outputs rewrite idempotently on retry)
        self.queue = []
        for r in served:
            r.done = True
        return served
