"""Batch-bucketed CNN serving over graph-planned programs.

The LM engine (serve/engine.py) keeps its compiled surface to two jitted
functions over fixed shapes; this engine applies the same discipline to
CNN inference traffic: the ONLY compiled programs are one jitted
whole-network GraphPlan execution per configured batch *bucket*.  Any
model exposing ``graph_plan``/``apply`` over the operator IR plugs in —
including the real network shapes (``resnet_like`` residual blocks,
``mobilenet_like`` depthwise stages, ``fire_like`` concats) whose whole
forward pass, head included, is one planned program.
Incoming image requests (each carrying one image or a small batch) are
flattened into per-image units and multiplexed onto the largest bucket
that fits the remaining queue — short remainders ride the smallest
bucket with zero-padded slots.  Plans are resolved once per bucket (and
persisted via the graph-level cache), so a warm engine serves any
request mix with zero plan() resolutions and at most ``len(buckets)``
compiled shapes.  A graph-wide ``PrecisionPolicy`` (``precision="bf16"``)
plans every bucket program in reduced precision end to end — fp32
master params, fp32 accumulation, precision-distinct cache keys.

Bucket-program building lives in ``BucketPrograms`` so the synchronous
drain engine here and the continuous-batching ``AsyncServeFrontend``
(serve/frontend.py) share one component: one geometry, one bucket set,
one packing dtype (``input_dtype()`` — warmup compiles exactly the
trace that serves), at most ``len(buckets)`` compiled programs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.dist import sharding as _sh


@dataclasses.dataclass
class ImageRequest:
    rid: int
    images: np.ndarray                  # (n, H, W, C), or (H, W, C) for one
    out: Optional[np.ndarray] = None    # (n, num_classes) once served
    done: bool = False

    def __post_init__(self):
        self.images = np.asarray(self.images)
        if self.images.ndim == 3:
            self.images = self.images[None]
        if self.images.ndim != 4:
            raise ValueError(f"images must be (n, H, W, C) or (H, W, C); "
                             f"got shape {self.images.shape}")


# ---------------------------------------------------------------------------
# packing: per-image units -> one contiguous batch array

def contiguous_blocks(chunk: Sequence[Tuple[ImageRequest, int]]
                      ) -> List[Tuple[ImageRequest, int, int]]:
    """Collapse ``(request, image_index)`` units into maximal contiguous
    ``(request, i0, i1)`` slices — units are generated in per-request
    index order, so consecutive units of one request always coalesce."""
    blocks: List[List] = []
    for r, i in chunk:
        if blocks and blocks[-1][0] is r and blocks[-1][2] == i:
            blocks[-1][2] = i + 1
        else:
            blocks.append([r, i, i + 1])
    return [tuple(b) for b in blocks]


def pack_units(chunk: Sequence[Tuple[ImageRequest, int]], bucket: int,
               image_shape: Tuple[int, int, int],
               dtype: np.dtype) -> np.ndarray:
    """Stack a chunk of units into a ``(bucket, H, W, C)`` batch in one
    vectorized pass: contiguous request slices are concatenated (no
    per-image copy loop) and short chunks get zero-padded tail slots.
    Every slice is cast to ``dtype`` so the packed batch always matches
    the dtype the bucket programs were compiled for."""
    parts = [np.asarray(r.images[i0:i1], dtype)
             for r, i0, i1 in contiguous_blocks(chunk)]
    pad = bucket - len(chunk)
    if pad:
        parts.append(np.zeros((pad,) + tuple(image_shape), dtype))
    return np.concatenate(parts, axis=0)


def scatter_outputs(chunk: Sequence[Tuple[ImageRequest, int]],
                    y: np.ndarray) -> None:
    """Write batch outputs back into each request's ``out`` rows,
    block-wise (the inverse of ``pack_units``; padded rows ignored)."""
    off = 0
    for r, i0, i1 in contiguous_blocks(chunk):
        if r.out is None:
            # empty, not zeros: every row is written exactly once (a
            # dispatched request is committed — all its units serve)
            r.out = np.empty((r.images.shape[0], y.shape[-1]), y.dtype)
        r.out[i0:i1] = y[off:off + (i1 - i0)]
        off += i1 - i0


# ---------------------------------------------------------------------------
# the reusable bucket-program component

class BucketPrograms:
    """One geometry's bucket programs: build, warm, pick, pack.

    Owns the ``{bucket: jitted whole-network program}`` table for one
    ``(image_shape, buckets)`` pair — the component both serving layers
    are built from (``CnnServeEngine`` holds one; ``AsyncServeFrontend``
    holds one per geometry).  ``input_dtype()`` is the single source of
    truth for the dtype requests are packed to AND the dtype
    ``warmup()``'s dummy compiles, so a warm program can never be asked
    to retrace at serve time because the two paths disagreed.

    **Sharded mode** (``mesh=`` a 1-D ``('data',)`` mesh from
    ``launch.mesh.make_serve_mesh``): the configured ``buckets`` become
    PER-SHARD capacities and the served (global) buckets are
    ``bucket * mesh_size`` — device-count-aware by construction, every
    global bucket a multiple of the mesh size, padding accounted per
    shard (``shard_units``).  Each program is the per-shard-geometry
    ``GraphPlan`` — so tuned launch configs persisted in autotune.json
    for that geometry are reused per shard unchanged — wrapped in
    ``shard_map`` over the mesh and jitted with the batch axis sharded
    and params replicated.  Because the per-shard body is traced at the
    per-shard batch shape, outputs are bitwise-identical to the
    single-device program at that bucket, whatever the device count.
    """

    def __init__(self, model, params, image_shape: Tuple[int, int, int], *,
                 buckets: Tuple[int, ...] = (1, 4, 8), algorithm="auto",
                 backend: Optional[str] = None, precision=None,
                 fuse: bool = True, input_dtype=None, mesh=None):
        self.model = model
        self.image_shape = tuple(map(int, image_shape))     # (H, W, C)
        self.mesh = mesh
        self.n_shards = int(np.prod(mesh.devices.shape)) if mesh else 1
        self.shard_buckets = tuple(sorted({int(b) for b in buckets}))
        if not self.shard_buckets or self.shard_buckets[0] < 1:
            raise ValueError(f"buckets must be positive ints; got {buckets}")
        # the buckets traffic is packed to: global batch sizes
        self.buckets = tuple(b * self.n_shards for b in self.shard_buckets)
        self.algorithm = algorithm
        self.backend = backend or jax.default_backend()
        self.precision = precision
        self.fuse = fuse
        self._input_dtype = np.dtype(input_dtype or np.float32)
        self._fns: Dict[int, Callable] = {}    # global bucket -> program
        self._plans: Dict[int, object] = {}    # global bucket -> GraphPlan
        # built once: NamedSharding construction is ~0.1ms of pure
        # Python, far too hot to repeat on every packed batch
        self._in_sharding = (None if mesh is None
                             else _sh.batch_sharded(mesh, ndim=4))
        # replicate params once onto the mesh (a tree already replicated
        # there — e.g. by a dispatcher shared across geometries — passes
        # through without any transfer)
        self.params = (params if mesh is None
                       else _sh.replicate_params(params, mesh))

    # ------------------------------------------------------------------
    def input_dtype(self) -> np.dtype:
        """The one packing/compile dtype.  Host inputs stay fp32 by
        default regardless of the PrecisionPolicy — the planned conv
        nodes cast operands to their spec dtype, and master inputs
        (like master params) are served full-precision.  Engines built
        with ``input_dtype=`` feed that dtype instead; either way,
        ``warmup`` and the packers both read THIS value."""
        return self._input_dtype

    @property
    def compiled_buckets(self) -> Tuple[int, ...]:
        """Batch sizes with a built program — never exceeds ``buckets``."""
        return tuple(sorted(self._fns))

    def serve_dtype(self, b: int) -> str:
        """The compute dtype(s) global bucket ``b``'s program serves its
        conv nodes in — ``"int8"`` for a fully quantized graph,
        ``"float32+int8"`` for a QuantPolicy with fp fallback nodes,
        ``"bfloat16"``/``"float32"`` for plain precision policies.
        Builds the bucket's plan on first use (same path as ``fn``)."""
        if b not in self._plans:
            self.fn(b)
        gp = self._plans[b]
        dtypes = sorted({p.spec.dtype for p in gp.conv_plans.values()})
        return "+".join(dtypes) if dtypes else str(self._input_dtype)

    def serve_dtypes(self) -> Dict[int, str]:
        """``{global bucket: serving dtype}`` over the configured
        buckets (plans are resolved as needed — cached thereafter)."""
        return {b: self.serve_dtype(b) for b in self.buckets}

    def pick_bucket(self, pending: int) -> int:
        """Largest bucket the pending unit count fills, else the
        smallest bucket (its tail slots ride zero-padded)."""
        fits = [b for b in self.buckets if b <= pending]
        return max(fits) if fits else self.buckets[0]

    def input_sharding(self):
        """How packed batches land on devices: batch axis sharded over
        the mesh, or None (default placement) unsharded — the value
        ``put()`` and the dispatch paths hand to ``jax.device_put``."""
        return self._in_sharding

    def put(self, xb: np.ndarray):
        """Explicitly place one packed batch (host → device(s)).  The
        serving layers only ever move inputs through here, so a
        ``jax.transfer_guard("disallow")`` around a warm serve loop
        proves params are never re-transferred."""
        return jax.device_put(xb, self.input_sharding())

    def shard_units(self, real: int, b: int) -> Optional[List[int]]:
        """Real (non-padded) images per mesh device for a batch of
        ``real`` units packed to global bucket ``b`` — shards take
        contiguous row slices, so padding concentrates in the trailing
        devices.  None when unsharded."""
        if self.mesh is None:
            return None
        per = b // self.n_shards
        return [max(0, min(per, real - i * per))
                for i in range(self.n_shards)]

    def _shard_plan(self, b: int):
        """The per-shard GraphPlan for global bucket ``b`` — the SAME
        plan (and tuned autotune.json launch configs) a single-device
        engine resolves for that per-shard batch geometry."""
        bs = b // self.n_shards
        return self.model.graph_plan(
            (bs,) + self.image_shape, backend=self.backend,
            force=None if self.algorithm == "auto" else self.algorithm,
            precision=self.precision, fuse=self.fuse)

    def fn(self, b: int) -> Callable:
        """The jitted program for global bucket ``b`` (built on first
        use).  Sharded mode wraps the per-shard program in ``shard_map``
        over the mesh: params replicated, batch axis split, outputs
        row-sharded — and the per-shard body traced at exactly the
        per-shard batch shape (bitwise parity with the single-device
        program)."""
        f = self._fns.get(b)
        if f is None:
            gp = self._shard_plan(b)
            self._plans[b] = gp
            if self.mesh is None:
                f = jax.jit(lambda params, xb: self.model.apply(
                    params, xb, graph_plan=gp))
            else:
                from jax.experimental.shard_map import shard_map
                from jax.sharding import PartitionSpec as P
                body = shard_map(
                    lambda params, xb: self.model.apply(
                        params, xb, graph_plan=gp),
                    mesh=self.mesh,
                    in_specs=(P(), P("data", None, None, None)),
                    out_specs=P("data"))
                # out sharding names only the leading (batch) dim so
                # any output rank stays row-sharded
                f = jax.jit(
                    body,
                    in_shardings=(_sh.replicated(self.mesh),
                                  self.input_sharding()),
                    out_shardings=_sh.batch_sharded(self.mesh, ndim=1))
            self._fns[b] = f
        return f

    def pack(self, chunk: Sequence[Tuple[ImageRequest, int]],
             bucket: int) -> np.ndarray:
        return pack_units(chunk, bucket, self.image_shape,
                          self.input_dtype())

    def warmup(self, *, measure: bool = False,
               tune: Optional[str] = None) -> Dict[int, float]:
        """Resolve + compile every bucket program in one sweep.

        ``tune="algo"`` first measure-autotunes each bucket's graph
        (GraphPlan.warmup) and ``tune="full"`` also sweeps the winning
        executors' candidate launch configs, so the compiled programs
        embed the measured ``(algorithm, config)`` winners — a served
        graph is tuned once here and replayed from cache ever after.
        ``measure=True`` is the back-compat spelling of ``tune="algo"``.
        The compile dummy is ``input_dtype()`` — exactly the dtype the
        packers feed — so warmup compiles exactly the trace that serves.
        Sharded mode tunes the PER-SHARD geometry (that is what each
        device executes) and places the dummy with the batch sharding.
        Returns per-bucket compile milliseconds keyed by global bucket.
        """
        if measure and tune is None:
            tune = "algo"
        H, W, C = self.image_shape
        out = {}
        for b in self.buckets:
            if tune is not None and self.algorithm == "auto":
                bs = b // self.n_shards
                self.model.graph_plan((bs, H, W, C), backend=self.backend,
                                      precision=self.precision,
                                      fuse=self.fuse) \
                    .warmup(tune=tune)
                # the measured sweep may have swapped node plans: an
                # already-compiled program would keep serving the stale
                # trace, so force a rebuild
                self._fns.pop(b, None)
                self._plans.pop(b, None)
            f = self.fn(b)
            x = self.put(np.zeros((b, H, W, C), self.input_dtype()))
            t0 = time.perf_counter()
            f(self.params, x).block_until_ready()
            out[b] = (time.perf_counter() - t0) * 1e3
        return out


# ---------------------------------------------------------------------------
# the synchronous drain engine

class CnnServeEngine:
    """Serve image-classification traffic through batch-bucketed plans."""

    def __init__(self, model, params, image_shape: Tuple[int, int, int], *,
                 buckets: Tuple[int, ...] = (1, 4, 8), algorithm="auto",
                 backend: Optional[str] = None, precision=None,
                 fuse: bool = True, input_dtype=None, mesh=None):
        # graph-wide PrecisionPolicy (e.g. "bf16") for every bucket
        # program; None defers to the model's own policy / fp32 inputs.
        # Master params stay fp32 — conv nodes cast per their specs, so
        # the same engine params serve any policy.  fuse=False serves
        # every bucket's unfused program (mirrors plan_graph's hatch).
        # mesh= shards every bucket program data-parallel (see
        # BucketPrograms; serve/distributed.py for the scheduler story).
        self.programs = BucketPrograms(
            model, params, image_shape, buckets=buckets,
            algorithm=algorithm, backend=backend, precision=precision,
            fuse=fuse, input_dtype=input_dtype, mesh=mesh)
        self.queue: List[ImageRequest] = []
        self.stats = {"requests": 0, "images": 0, "padded_slots": 0,
                      "batches": {b: 0 for b in self.programs.buckets}}

    # -- thin views over the shared component --------------------------
    @property
    def model(self):
        return self.programs.model

    @property
    def params(self):
        return self.programs.params

    @property
    def image_shape(self) -> Tuple[int, int, int]:
        return self.programs.image_shape

    @property
    def buckets(self) -> Tuple[int, ...]:
        return self.programs.buckets

    @property
    def precision(self):
        return self.programs.precision

    @property
    def compiled_buckets(self) -> Tuple[int, ...]:
        return self.programs.compiled_buckets

    @property
    def _fns(self) -> Dict[int, Callable]:
        # the live program table (tests and callers may inspect/patch it)
        return self.programs._fns

    def serve_dtypes(self) -> Dict[int, str]:
        """Per-bucket serving dtype (see ``BucketPrograms.serve_dtype``)
        — ``"int8"`` buckets are proof the engine serves quantized."""
        return self.programs.serve_dtypes()

    def _bucket_fn(self, b: int) -> Callable:
        return self.programs.fn(b)

    def _pick_bucket(self, pending: int) -> int:
        return self.programs.pick_bucket(pending)

    def warmup(self, *, measure: bool = False,
               tune: Optional[str] = None) -> Dict[int, float]:
        """Resolve + compile every bucket program (see
        ``BucketPrograms.warmup``)."""
        return self.programs.warmup(measure=measure, tune=tune)

    # ------------------------------------------------------------------
    def submit(self, req: ImageRequest) -> None:
        if tuple(req.images.shape[1:]) != self.image_shape:
            raise ValueError(f"request {req.rid}: image shape "
                             f"{req.images.shape[1:]} != engine shape "
                             f"{self.image_shape}")
        self.queue.append(req)

    def run(self) -> List[ImageRequest]:
        """Drain the queue; returns the served requests (outputs filled).

        Requests are flattened to per-image units and packed batch by
        batch: the largest bucket that the remaining unit count fills,
        else the smallest bucket with padded (zero) slots.
        """
        served, units = list(self.queue), []
        for r in served:
            units.extend((r, i) for i in range(r.images.shape[0]))
        cursor = 0
        while cursor < len(units):
            b = self.programs.pick_bucket(len(units) - cursor)
            chunk = units[cursor:cursor + b]
            xb = self.programs.pack(chunk, b)
            y = np.asarray(self.programs.fn(b)(self.params,
                                               self.programs.put(xb)))
            scatter_outputs(chunk, y)
            self.stats["batches"][b] += 1
            self.stats["padded_slots"] += b - len(chunk)
            self.stats["images"] += len(chunk)
            cursor += b
        # only a fully drained queue is cleared: a failure above leaves
        # every request submitted (outputs rewrite idempotently on retry)
        self.queue = []
        self.stats["requests"] += len(served)
        for r in served:
            r.done = True
        return served
