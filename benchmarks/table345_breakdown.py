"""Paper tables 3/4/5: per-kernel time breakdown for the profiled configs.

Times our stage-1 / stage-2 split (the paper's scalar_prods_kernel /
sum_kernel) against the library and explicit-GEMM baselines, plus the
beyond-paper fused variant — reproducing the tables' structure: for 1x1
configs stage 2 is absent; for KxK the paper found stage 1 dominates
(91-99 %) and stage 2 is the small remainder.  The PR-10 executors
(tiled Pallas winograd, im2col-free direct) and the jnp winograd
reference add per-variant rows on the configs they support, timed
through forced plans so each is measured exactly as deployed.

Besides the CSV rows, every run writes ``BENCH_table345.json``
(benchmarks/common.write_json): one machine-readable record per
(config, variant) with the planner's negotiated algorithm and its
resolved launch config for the configuration, so the per-config perf
trajectory is tracked across PRs.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, time_fn, write_json
from repro.configs.cnn_paper import PROFILED
from repro.core import convspec as cs
from repro.core import cuconv as cc
from repro.core import executors as ex
from repro.quant.accuracy import spec_accuracy


def run(quick=True):
    rng = np.random.default_rng(0)
    rows = ["# table345_breakdown: name,us_per_call,derived"]
    records = []
    for label, (hw, batch, k, M, C) in PROFILED.items():
        x = jnp.asarray(rng.normal(size=(batch, hw, hw, C)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(k, k, C, M)), jnp.float32)
        # what the planner would run for this configuration, launch
        # config included (measured if a tuning sweep ran on this
        # machine, the executor's model default otherwise)
        spec = cs.ConvSpec.for_conv(x, w, 1, "same")
        plan = cs.plan(spec)
        planned = {"algorithm": plan.algorithm, "source": plan.source,
                   "config": plan.config.as_dict() if plan.config else {},
                   "config_source": plan.config_source}
        s1 = jax.jit(functools.partial(cc.cuconv_stage1, stride=1,
                                       padding="same"))
        t1 = time_fn(s1, x, w, repeats=3, warmup=1)
        temps = s1(x, w)
        if k > 1:
            s2 = jax.jit(cc.cuconv_stage2)
            t2 = time_fn(s2, temps, repeats=3, warmup=1)
        else:
            t2 = 0.0                      # paper: second kernel not needed
        t_fused = time_fn(jax.jit(functools.partial(
            cc.conv_cuconv, stride=1, padding="same")), x, w,
            repeats=3, warmup=1)
        t_lax = time_fn(jax.jit(functools.partial(
            cc.conv_lax, stride=1, padding="same")), x, w,
            repeats=3, warmup=1)
        t_im2col = time_fn(jax.jit(functools.partial(
            cc.conv_im2col, stride=1, padding="same")), x, w,
            repeats=3, warmup=1)
        stage1_frac = t1 / max(t1 + t2, 1e-9) * 100
        rows.append(csv_row(f"t345/{label}/stage1", t1,
                            f"{stage1_frac:.1f}% of two-stage total"))
        if k > 1:
            rows.append(csv_row(f"t345/{label}/stage2", t2,
                                f"{100-stage1_frac:.1f}%"))
        rows.append(csv_row(f"t345/{label}/fused", t_fused,
                            f"fusion_gain={(t1+t2)/max(t_fused,1e-9):.2f}x"))
        rows.append(csv_row(f"t345/{label}/library", t_lax, ""))
        rows.append(csv_row(f"t345/{label}/im2col_gemm", t_im2col, ""))
        config = f"{hw}x{hw}x{C} b{batch} k{k} m{M}"
        # PR-10 executors (and the jnp winograd reference), timed through
        # forced plans so launch-config resolution + epilogue are included
        # exactly as plan() deploys them
        alt = {}
        for name in ("winograd", "winograd_pallas", "direct"):
            exe = ex.get(name)
            if not exe.supports(spec)[0]:
                continue
            p = cs.plan(spec, force=name)
            t_alt = time_fn(jax.jit(lambda xx, ww, _p=p: _p(xx, ww)),
                            x, w, repeats=3, warmup=1)
            alt[name] = (t_alt, p)
            rows.append(csv_row(
                f"t345/{label}/{name}", t_alt,
                f"cfg[{p.config_source}]="
                f"{p.config.key() if p.config else '-'} "
                f"vs_library={t_lax / max(t_alt, 1e-9):.2f}x"))
            records.append({
                "name": f"t345/{label}/{name}", "config": config,
                "dtype": "float32", "us": t_alt,
                "planned": {
                    "algorithm": p.algorithm, "source": p.source,
                    "config": p.config.as_dict() if p.config else {},
                    "config_source": p.config_source}})
        # beyond-paper int8 variant: the quantized executor on the same
        # configuration (dynamic activation scale — no calibration in a
        # per-call benchmark), with its per-layer accuracy delta vs fp32
        plan8 = cs.plan(dataclasses.replace(spec, dtype="int8"))
        t_int8 = time_fn(jax.jit(lambda x, w: plan8(x, w, None, None)),
                         x, w, repeats=3, warmup=1)
        acc8 = spec_accuracy(spec)
        rows.append(csv_row(
            f"t345/{label}/int8", t_int8,
            f"{plan8.algorithm} rel_err={acc8['rel_err']:.4f} "
            f"vs_library={t_lax / max(t_int8, 1e-9):.2f}x"))
        for variant, us in (("stage1", t1), ("stage2", t2),
                            ("fused", t_fused), ("library", t_lax),
                            ("im2col_gemm", t_im2col)):
            if variant == "stage2" and k == 1:
                continue
            records.append({"name": f"t345/{label}/{variant}",
                            "config": config, "dtype": "float32",
                            "us": us, "planned": planned})
        records.append({
            "name": f"t345/{label}/int8", "config": config,
            "dtype": "int8", "us": t_int8, "accuracy": acc8,
            "planned": {
                "algorithm": plan8.algorithm, "source": plan8.source,
                "config": (plan8.config.as_dict() if plan8.config
                           else {}),
                "config_source": plan8.config_source}})
    path = write_json("table345", records)
    rows.append(f"# wrote {path}")
    return rows
