"""LM-substrate micro-benchmarks (framework layers around the paper's op):
smoke-scale train-step and decode-step wall time per architecture family,
plus the tap-decomposed conv1d vs its reference inside the SSM block.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, time_fn
from repro.configs.base import get_config, smoke_variant
from repro.models import lm
from repro.launch.steps import make_train_step
from repro.optim import adamw_init

ARCHS_QUICK = ["qwen2-1.5b", "mamba2-1.3b", "deepseek-moe-16b",
               "jamba-v0.1-52b"]


def run(quick=True):
    rows = ["# lm_substrate: name,us_per_call,derived (smoke configs, CPU)"]
    rng = np.random.default_rng(0)
    archs = ARCHS_QUICK if quick else sorted(
        __import__("repro.configs.base", fromlist=["list_archs"]).list_archs())
    for arch in archs:
        cfg = dataclasses.replace(smoke_variant(get_config(arch)),
                                  grad_accum=1)
        params = lm.init_lm(cfg, jax.random.PRNGKey(0))
        B, S = 4, 32
        batch = {"labels": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                    (B, S)), jnp.int32)}
        if cfg.input_mode == "tokens":
            batch["tokens"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        else:
            batch["embeds"] = jnp.asarray(
                rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
            if cfg.mrope_sections:
                batch["positions"] = jnp.broadcast_to(
                    jnp.arange(S, dtype=jnp.int32), (3, B, S))
        state = {"params": params, "opt": adamw_init(params),
                 "step": jnp.zeros((), jnp.int32)}
        step = jax.jit(make_train_step(cfg))
        t = time_fn(lambda s=state, b=batch: step(s, b)[1]["loss"],
                    repeats=3, warmup=1)
        tok_s = B * S / (t / 1e6)
        rows.append(csv_row(f"lm/{arch}/train_step_smoke", t,
                            f"tokens_per_s={tok_s:.0f}"))
    # conv1d tap kernel vs jnp ref (the paper's technique inside Mamba)
    from repro.kernels import ops, ref
    x = jnp.asarray(rng.normal(size=(4, 512, 128)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(4, 128)), jnp.float32)
    t_ref = time_fn(jax.jit(ref.conv1d_ref), x, w, repeats=3, warmup=1)
    rows.append(csv_row("lm/conv1d_tap_jnp_ref", t_ref,
                        "XLA-fused tap decomposition (B=4,L=512,D=128)"))
    return rows
