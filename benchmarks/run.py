"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  table1_inventory    paper Table 1 (CNN conv config inventory)
  paper_figures       paper Figures 5/6/7 (speedup vs best library conv)
  table345_breakdown  paper Tables 3/4/5 (per-kernel time split)
  graph_serve         graph-planned CNN programs + batch-bucketed serving
  loadgen             open-loop Poisson curves + multi-device scaling sweep
  lm_substrate        framework-layer micro-benchmarks

``--full`` sweeps every distinct config (slow on 1 CPU core);
the default quick set covers every profiled configuration of the paper.
Roofline terms for the assigned architectures come from the dry-run
artifacts (python -m repro.roofline.analysis), not from here.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset")
    args = ap.parse_args(argv)
    quick = not args.full

    from benchmarks import (graph_serve, lm_substrate, loadgen,
                            paper_figures, table1_inventory,
                            table345_breakdown)
    mods = {
        "table1_inventory": table1_inventory,
        "paper_figures": paper_figures,
        "table345_breakdown": table345_breakdown,
        "graph_serve": graph_serve,
        "loadgen": loadgen,
        "lm_substrate": lm_substrate,
    }
    names = args.only.split(",") if args.only else list(mods)
    print("name,us_per_call,derived")
    for name in names:
        for row in mods[name].run(quick=quick):
            print(row)
        sys.stdout.flush()


if __name__ == "__main__":
    main()
