"""Open-loop Poisson load generation + the multi-device scaling sweep.

Two records for BENCH_graph_serve.json (merged next to graph_serve's
via ``write_json(merge=True)``), both driving the DIST_SMOKE tiny_cnn
deployment through ``ShardedServeDispatcher`` (serve/distributed.py):

* ``serve/loadgen`` — an OPEN-LOOP load generator: arrivals are drawn
  from a Poisson process at each offered rate and submitted on
  schedule whether or not the dispatcher has caught up, so queueing
  delay is never masked by closed-loop back-pressure.  Sweeping the
  offered rate produces the latency-vs-offered-throughput curve: flat
  percentiles while capacity holds, then the knee where achieved
  throughput saturates and latency is queue depth.

* ``sharded_scaling`` — the subsystem's acceptance record: the same
  deployment driven to saturation in a FRESH SUBPROCESS per forced
  host-platform device count (``--xla_force_host_platform_device_count``
  must be set before jax imports, hence ``--worker`` mode), recording
  throughput, per-device utilization, and a SHA-1 digest over every
  output.  On one CPU core the scaling comes from the device-count-
  aware global buckets (per-shard bucket × mesh size) amortizing the
  fixed per-batch scheduling cost over more images; the digests assert
  the sharded results are bitwise-identical to the single-device
  ``CnnServeEngine`` at every device count.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Sequence, Tuple

import numpy as np

from benchmarks.common import csv_row, write_json

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: the DIST_SMOKE geometry both records drive
SCALING_SHAPE: Tuple[int, int, int] = (8, 8, 3)
#: the device counts the scaling sweep forces
DEVICE_COUNTS: Tuple[int, ...] = (1, 2, 4)


def _images(n: int, seed: int) -> np.ndarray:
    """The deterministic image pool: identical bytes at every device
    count, so output digests are comparable across workers."""
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n,) + SCALING_SHAPE).astype(np.float32)


def _dispatcher(model, params, buckets):
    from repro.configs.serve import DIST_SMOKE
    from repro.serve import ShardedServeDispatcher
    return ShardedServeDispatcher(
        model, params, {SCALING_SHAPE: buckets},
        process_index=0, process_count=1,
        max_wait_ms=DIST_SMOKE.max_wait_ms,
        default_deadline_ms=DIST_SMOKE.default_deadline_ms,
        pipeline_depth=DIST_SMOKE.pipeline_depth)


# ---------------------------------------------------------------------------
# worker: one forced-device-count throughput + digest measurement

def worker(images: int, seed: int, reps: int = 3) -> Dict:
    """Saturation throughput of the DIST_SMOKE deployment at THIS
    process's device count, plus bitwise evidence: a digest over the
    dispatcher's outputs (request order) and the same digest from the
    single-device synchronous engine on identical inputs.

    Throughput is the DRAIN rate: the backlog is queued first and only
    ``run()`` is timed — the server-side number an open-loop generator
    saturating the dispatcher would observe, with the client's submit
    cost off the clock.  Best of ``reps`` drains (single-core CI wall
    clocks are noisy); every rep must reproduce the same digest."""
    import jax

    from repro.configs.serve import DIST_SMOKE
    from repro.models.cnn import tiny_cnn
    from repro.serve import CnnServeEngine, ImageRequest, ServeRequest

    buckets = DIST_SMOKE.geometry_map()[SCALING_SHAPE]
    model = tiny_cnn()
    params = model.init(jax.random.PRNGKey(0))
    imgs = _images(images, seed)

    disp = _dispatcher(model, params, buckets)
    disp.warmup()
    for i in range(8):                       # prime the dispatch path
        disp.submit(ServeRequest(rid=10**9 + i, images=imgs[i:i + 1]))
    disp.run()

    best_dt, digests, exactly_once = float("inf"), set(), True
    for rep in range(reps):
        base = rep * images
        for i in range(images):
            disp.submit(ServeRequest(rid=base + i, images=imgs[i:i + 1]))
        t0 = time.perf_counter()
        done = disp.run()
        best_dt = min(best_dt, time.perf_counter() - t0)
        done.sort(key=lambda r: r.rid)
        exactly_once &= (
            len(done) == images
            and [r.rid for r in done] == list(range(base, base + images))
            and all(r.status == "served" for r in done))
        outs = np.concatenate([r.out for r in done])
        digests.add(hashlib.sha1(outs.tobytes()).hexdigest())
    dt = best_dt
    st = disp.stats()

    # the single-device reference: same model/params/images through the
    # synchronous engine, unsharded, at the per-shard bucket sizes —
    # the per-shard batch shape every mesh device executes
    eng = CnnServeEngine(model, params, SCALING_SHAPE, buckets=buckets)
    eng.warmup()
    for i in range(images):
        eng.submit(ImageRequest(rid=i, images=imgs[i:i + 1]))
    ref = eng.run()
    ref.sort(key=lambda r: r.rid)
    ref_outs = np.concatenate([r.out for r in ref])

    return {
        "device_count": int(disp.n_devices),
        "global_buckets": list(disp.global_buckets(SCALING_SHAPE)),
        "images": images,
        "elapsed_ms": dt * 1e3,
        "img_per_s": images / dt,
        "exactly_once": exactly_once,
        # one digest per drain rep — a singleton set is determinism
        # evidence before it is compared across device counts
        "digest": sorted(digests)[0] if len(digests) == 1 else "UNSTABLE",
        "engine_digest": hashlib.sha1(ref_outs.tobytes()).hexdigest(),
        "per_device_utilization": [p["utilization"]
                                   for p in st["partitions"]],
        "batches": st["batches_by_program"],
    }


def _run_worker(device_count: int, images: int, seed: int) -> Dict:
    """Fresh interpreter per device count: the forced-host-platform
    flag only takes effect before jax initialises."""
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith("--xla_force_host_platform_device_count")]
    flags.append(f"--xla_force_host_platform_device_count={device_count}")
    env["XLA_FLAGS"] = " ".join(flags)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (_ROOT, os.path.join(_ROOT, "src"),
                    env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.loadgen", "--worker",
         "--images", str(images), "--seed", str(seed)],
        env=env, cwd=_ROOT, capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(
            f"scaling worker (devices={device_count}) failed:\n"
            f"{proc.stderr[-2000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def scaling_record(images: int, seed: int = 0) -> Dict:
    from repro.configs.serve import DIST_SMOKE
    runs = [_run_worker(n, images, seed) for n in DEVICE_COUNTS]
    base = runs[0]["img_per_s"]
    digests = ({r["digest"] for r in runs}
               | {r["engine_digest"] for r in runs})
    return {
        "name": "sharded_scaling",
        "model": "tiny_cnn",
        "geometry": "x".join(map(str, SCALING_SHAPE)),
        "per_shard_buckets": list(DIST_SMOKE.geometry_map()[SCALING_SHAPE]),
        "images": images,
        "runs": runs,
        "speedups": {str(r["device_count"]): r["img_per_s"] / base
                     for r in runs},
        "bitwise_identical": len(digests) == 1,
        "exactly_once": all(r["exactly_once"] for r in runs),
    }


# ---------------------------------------------------------------------------
# open-loop Poisson curve (current process's devices)

def poisson_curve(rates: Sequence[float], duration_s: float,
                  seed: int = 0) -> Dict:
    import jax

    from repro.configs.serve import DIST_SMOKE
    from repro.models.cnn import tiny_cnn
    from repro.serve import ServeRequest

    model = tiny_cnn()
    params = model.init(jax.random.PRNGKey(0))
    disp = _dispatcher(model, params,
                       DIST_SMOKE.geometry_map()[SCALING_SHAPE])
    disp.warmup()
    pool = _images(64, seed)
    rng = np.random.default_rng(seed)
    rid, points = 0, []
    for rate in rates:
        n_req = max(16, int(rate * duration_s))
        telem = disp.frontend.telemetry
        start = len(telem.requests)
        # open loop: arrival times are fixed up front by the Poisson
        # process — a slow server gets further behind, not less traffic
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_req))
        t0 = time.perf_counter()
        k = 0
        while k < n_req:
            now = time.perf_counter() - t0
            while k < n_req and arrivals[k] <= now:
                disp.submit(ServeRequest(
                    rid=rid, images=pool[rid % len(pool)][None]))
                rid += 1
                k += 1
            disp.poll()
        disp.run()                           # drain the tail
        elapsed = time.perf_counter() - t0
        traces = telem.requests[start:]
        totals = [t.total_ms for t in traces if t.status == "served"]
        points.append({
            "offered_rps": float(rate),
            "achieved_rps": n_req / elapsed,
            "requests": n_req,
            "p50_ms": float(np.percentile(totals, 50)),
            "p95_ms": float(np.percentile(totals, 95)),
            "p99_ms": float(np.percentile(totals, 99)),
            "deadline_misses": sum(1 for t in traces
                                   if t.status != "served"),
        })
    return {
        "name": "serve/loadgen",
        "model": "tiny_cnn",
        "geometry": "x".join(map(str, SCALING_SHAPE)),
        "devices": int(disp.n_devices),
        "duration_s": duration_s,
        "points": points,
    }


# ---------------------------------------------------------------------------

def run(quick: bool = True) -> List[str]:
    rates = (250.0, 1000.0, 4000.0) if quick else (
        250.0, 500.0, 1000.0, 2000.0, 4000.0, 8000.0)
    curve = poisson_curve(rates, duration_s=0.5 if quick else 1.0)
    # a deep backlog (≈1-2s of queueing at capacity) keeps every drain
    # in the saturated regime the scaling claim is about
    scaling = scaling_record(images=4096)

    rows = []
    for p in curve["points"]:
        rows.append(csv_row(
            f"serve/loadgen_r{int(p['offered_rps'])}",
            p["p95_ms"] * 1e3,
            f"achieved_rps={p['achieved_rps']:.0f} "
            f"p50_ms={p['p50_ms']:.2f}"))
    for r in scaling["runs"]:
        n = r["device_count"]
        rows.append(csv_row(
            f"serve/sharded_scaling_d{n}",
            1e6 / r["img_per_s"],
            f"img_per_s={r['img_per_s']:.0f} "
            f"speedup={scaling['speedups'][str(n)]:.2f} "
            f"bitwise={'ok' if scaling['bitwise_identical'] else 'FAIL'}"))
    write_json("graph_serve", [curve, scaling], merge=True)
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true",
                    help="one forced-device-count measurement; prints "
                         "a JSON line (internal: scaling_record spawns "
                         "these with XLA_FLAGS preset)")
    ap.add_argument("--images", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    if args.worker:
        print(json.dumps(worker(args.images, args.seed)))
        return
    print("name,us_per_call,derived")
    for row in run(quick=not args.full):
        print(row)


if __name__ == "__main__":
    main()
