"""Paper figures 5/6/7: speedup of cuConv vs the best library convolution,
by filter size (1x1 / 3x3 / 5x5), across CNN configs x batch sizes.

The paper compares against the best of all cuDNN variants on V100; this
CPU container's analogue is the best of {lax (library), im2col (explicit
GEMM)} — relative *algorithm* behaviour on XLA:CPU, not TPU wall-clock
(DESIGN.md §7).  ``quick`` benchmarks a stratified subset (the paper's
profiled configs + spread across nets/batches); ``full`` sweeps all
distinct configs x (1, 8, 16) batches.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, time_fn
from repro.configs import cnn_paper as cp
from repro.core import executors as ex
from repro.core.convspec import ConvSpec, plan

# our kernels (never counted into the "best library" denominator; the
# paper's speedup baseline is the best *library* convolution)
OURS = ("cuconv", "cuconv_two_stage", "direct", "winograd_pallas")

QUICK_SET = [
    # (hw, k, M, C) drawn from the paper's profiled configs + coverage
    (7, 1, 256, 832),      # t3 A: paper's 2.29x headline config
    (14, 1, 1024, 256),    # t3 B
    (27, 1, 256, 64),      # t3 C
    (7, 3, 384, 192),      # t4 A
    (13, 3, 384, 384),     # t4 B
    (7, 5, 128, 48),       # t5 A/B
    (55, 1, 64, 16),       # squeezenet early
    (56, 3, 192, 64),      # googlenet conv3
    (14, 3, 512, 512),     # vgg19 late
]
QUICK_BATCHES = (1, 8)


def _bench_config(hw, k, M, C, batch, rng):
    """Per-algorithm times through the *registered executor* path
    (forced plans), so each variant is measured exactly as plan() would
    deploy it — launch config resolution and epilogue included (the PR 2
    measure_algorithm contract), not a bare-fn approximation."""
    x = jnp.asarray(rng.normal(size=(batch, hw, hw, C)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, k, C, M)), jnp.float32)
    spec = ConvSpec((batch, hw, hw, C), (k, k, C, M), (1, 1),
                    ((k - 1) // 2, (k - 1) // 2))
    names = ["lax", "im2col", "cuconv", "cuconv_two_stage", "direct"]
    if k == 3:
        names += ["winograd", "winograd_pallas"]
    times = {}
    for name in names:
        if not ex.get(name).supports(spec)[0]:
            continue
        p = plan(spec, force=name)
        f = jax.jit(lambda xx, ww, _p=p: _p(xx, ww))
        times[name] = time_fn(f, x, w, repeats=3, warmup=1)
    return times


def run(quick=True):
    rng = np.random.default_rng(0)
    rows = ["# fig567_speedup: name,us_per_call,derived "
            "(speedup = best-library / cuconv)"]
    if quick:
        configs = QUICK_SET
        batches = QUICK_BATCHES
    else:
        configs = cp.all_distinct()
        batches = (1, 8, 16)
    wins, total = 0, 0
    by_k = {}
    for (hw, k, M, C) in configs:
        for b in batches:
            t = _bench_config(hw, k, M, C, b, rng)
            lib_best = min(v for n, v in t.items() if n not in OURS)
            speedup = lib_best / t["cuconv"]
            total += 1
            wins += speedup > 1.0
            by_k.setdefault(k, []).append(speedup)
            extra = "".join(f" {n}={t[n]:.0f}us"
                            for n in ("winograd", "winograd_pallas",
                                      "direct") if n in t)
            # what the ConvSpec planner would run for this configuration
            p = plan(ConvSpec((b, hw, hw, C), (k, k, C, M), (1, 1),
                              ((k - 1) // 2, (k - 1) // 2)))
            chosen = (f" plan={p.algorithm}[{p.source}]"
                      + (f"@{t[p.algorithm]:.0f}us"
                         if p.algorithm in t else ""))
            rows.append(csv_row(
                f"fig{5 if k == 1 else (6 if k == 3 else 7)}/"
                f"{hw}-{M}-{C}-b{b}", t["cuconv"],
                f"speedup={speedup:.2f} lax={t['lax']:.0f}us "
                f"im2col={t['im2col']:.0f}us "
                f"two_stage={t['cuconv_two_stage']:.0f}us" + extra + chosen))
    for k, sp in sorted(by_k.items()):
        rows.append(csv_row(
            f"fig567/summary_{k}x{k}", 0.0,
            f"mean_speedup={np.mean(sp):.2f} max={np.max(sp):.2f} "
            f"n={len(sp)}"))
    rows.append(csv_row("fig567/summary_overall", 0.0,
                        f"faster_frac={wins/max(total,1)*100:.1f}% "
                        f"(paper: 8.31% on V100 vs best cuDNN)"))
    return rows
