"""Shared timing harness for the benchmark suite."""
from __future__ import annotations

import functools
import time
from typing import Callable

import jax
import numpy as np


def time_fn(fn: Callable, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Median wall time in microseconds of a jitted call (post-compile)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def csv_row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"
