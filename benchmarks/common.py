"""Shared timing + result-recording harness for the benchmark suite."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

#: persisted benchmark-artifact schema (BENCH_*.json)
BENCH_SCHEMA = 1


def time_fn(fn: Callable, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Median wall time in microseconds of a jitted call (post-compile)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def csv_row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"


def write_json(stem: str, records: List[Dict],
               out_dir: Optional[str] = None, merge: bool = False) -> str:
    """Persist machine-readable benchmark results as ``BENCH_<stem>.json``.

    ``records`` is a list of dicts (name, config, dtype, algorithm,
    tuned config, µs, ...); the envelope carries a schema version and
    the backend so the perf trajectory can be tracked (and CI-archived)
    across PRs.  Returns the written path.  ``$REPRO_BENCH_DIR``
    overrides the output directory (default: CWD).

    With ``merge=True`` an existing artifact's records are kept, minus
    any whose ``name`` a new record replaces — so two benchmark modules
    (e.g. graph_serve and loadgen) can contribute to ONE stem without
    clobbering each other, in either run order.
    """
    out_dir = out_dir or os.environ.get("REPRO_BENCH_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{stem}.json")
    if merge and os.path.exists(path):
        with open(path) as f:
            old = json.load(f).get("records", [])
        fresh = {r.get("name") for r in records}
        records = [r for r in old if r.get("name") not in fresh] + records
    with open(path, "w") as f:
        json.dump({"schema": BENCH_SCHEMA,
                   "backend": jax.default_backend(),
                   "records": records}, f, indent=1, sort_keys=True)
    return path
