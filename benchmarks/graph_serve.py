"""Graph-planned CNN inference benchmark (the deployment story).

Plans the SqueezeNet-flavoured stack ONCE per batch bucket through the
graph API, reports the one-sweep warmup cost and the steady-state
per-image latency of each bucketed program, and drives a mixed-size
request stream through the batch-bucketed CnnServeEngine — the number
the ROADMAP north-star cares about (planned programs serving traffic),
alongside the per-layer plan table the per-call benchmarks print.

The IR-era models (resnet_like with residual adds + pooling,
mobilenet_like with depthwise/grouped stages) run the same steady-state
sweep: their ENTIRE forward pass is one planned program, so the rows
are directly comparable.  Every row carries a ``dtype=`` column; the IR
models run under both the fp32 default and ``PrecisionPolicy("bf16")``
(fp32 master params, fp32 accumulation, precision-distinct plan-cache
keys), so the reduced-precision deployment story is benchmarked on the
same programs.

The async serving front end (serve/frontend.py) gets its own section:
mixed-deadline traffic at TWO image resolutions through ONE
``AsyncServeFrontend`` (the ``configs/serve.py`` smoke deployment),
recording per-request latency rollups (p50/p95/p99 for
queue/transfer/compute/total), the deadline-miss count (zero at the
default SLO), and the double-buffering overlap evidence — steady-state
batch interval vs transfer and compute timed separately.

Besides the CSV rows, every run writes ``BENCH_graph_serve.json``
(benchmarks/common.write_json): machine-readable records — name, model
config, dtype, per-node algorithms with their resolved launch configs,
µs — so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, time_fn, write_json
from repro.configs.serve import SMOKE_FRONTEND
from repro.models.cnn import mobilenet_like, resnet_like, squeezenet_like
from repro.quant import Calibrator, QuantPolicy, accuracy_report
from repro.serve.cnn import CnnServeEngine, ImageRequest
from repro.serve.frontend import AsyncServeFrontend, ServeRequest

HW, C = 32, 3


def _plan_record(gp):
    """Per-node (algorithm, launch config) provenance of a GraphPlan."""
    return {n: {"algorithm": p.algorithm,
                "config": p.config.as_dict() if p.config else {},
                "config_source": p.config_source}
            for n, p in gp.conv_plans.items()}


def run(quick=True):
    rng = np.random.default_rng(0)
    rows = ["# graph_serve: one planned program per batch bucket "
            "(squeezenet-like stack, 32x32x3)"]
    records = []
    model = squeezenet_like()
    params = model.init(jax.random.PRNGKey(0))
    buckets = (1, 4) if quick else (1, 4, 16)

    for b in buckets:
        gp = model.graph_plan((b, HW, HW, C))
        stats = gp.warmup()
        algos = ",".join(sorted({r["algorithm"] for r in stats["nodes"]}))
        rows.append(csv_row(f"graph/warmup_b{b}", stats["total_ms"] * 1e3,
                            f"dtype=float32 nodes={len(stats['nodes'])} "
                            f"source={gp.source} algos={algos}"))
        fn = jax.jit(lambda p, x, gp=gp: model.apply(p, x, graph_plan=gp))
        x = jnp.asarray(rng.normal(size=(b, HW, HW, C)), jnp.float32)
        us = time_fn(fn, params, x, repeats=3, warmup=1)
        rows.append(csv_row(f"graph/steady_b{b}", us,
                            f"dtype=float32 per_image_us={us / b:.1f}"))
        records.append({"name": f"graph/steady_b{b}",
                        "config": f"squeezenet_like b{b} {HW}x{HW}x{C}",
                        "dtype": "float32", "us": us,
                        "plans": _plan_record(gp)})

    eng = CnnServeEngine(model, params, (HW, HW, C), buckets=buckets)
    eng.warmup()
    sizes = ([1, 3, 2, 5, 1] if quick
             else [1, 3, 2, 5, 1, 16, 7, 4, 2, 9])
    for i, n in enumerate(sizes):
        eng.submit(ImageRequest(rid=i, images=rng.normal(
            size=(n, HW, HW, C)).astype(np.float32)))
    import time as _t
    t0 = _t.perf_counter()
    eng.run()
    total_us = (_t.perf_counter() - t0) * 1e6
    used = {b: n for b, n in eng.stats["batches"].items() if n}
    rows.append(csv_row(
        "graph/serve_stream", total_us,
        f"dtype=float32 images={eng.stats['images']} "
        f"batches={sum(used.values())} "
        f"buckets_used={len(used)}/{len(eng.buckets)} "
        f"padded={eng.stats['padded_slots']} "
        f"per_image_us={total_us / max(eng.stats['images'], 1):.1f}"))
    records.append({"name": "graph/serve_stream",
                    "config": f"squeezenet_like buckets={list(buckets)}",
                    "dtype": "float32", "us": total_us,
                    "images": eng.stats["images"],
                    "padded_slots": eng.stats["padded_slots"]})

    # IR models: residual / pool / depthwise forward passes as ONE
    # program, under the fp32 default, the bf16 precision policy, and
    # the calibrated int8 QuantPolicy (fp first/last, int8 inside)
    for mk in ((resnet_like,) if quick else (resnet_like, mobilenet_like)):
        m = mk()
        p = m.init(jax.random.PRNGKey(0))
        xc = np.asarray(rng.normal(size=(4, HW, HW, C)), np.float32)
        m.graph_plan(xc.shape).warmup(calibrate=Calibrator(xc, p))
        for precision in (None, "bf16", QuantPolicy()):
            gp = m.graph_plan((1, HW, HW, C), precision=precision)
            dtype = "+".join(sorted({n.spec.dtype
                                     for n in gp.graph.conv_nodes}))
            stats = gp.warmup()
            algos = ",".join(sorted({r["algorithm"]
                                     for r in stats["nodes"]}))
            rows.append(csv_row(
                f"graph/{m.name}_warmup_{dtype}", stats["total_ms"] * 1e3,
                f"dtype={dtype} ir_nodes={len(gp.graph)} "
                f"convs={len(stats['nodes'])} source={gp.source} "
                f"algos={algos}"))
            fn = jax.jit(lambda pp, x, gp=gp, m=m: m.apply(
                pp, x, graph_plan=gp))
            x = jnp.asarray(rng.normal(size=(1, HW, HW, C)), jnp.float32)
            us = time_fn(fn, p, x, repeats=3, warmup=1)
            rows.append(csv_row(
                f"graph/{m.name}_steady_b1_{dtype}", us,
                f"dtype={dtype} whole-network program "
                f"(pool/add/head inside)"))
            record = {"name": f"graph/{m.name}_steady_b1_{dtype}",
                      "config": f"{m.name} b1 {HW}x{HW}x{C}",
                      "dtype": dtype, "us": us,
                      "fused": dict(gp.fused),
                      "plans": _plan_record(gp)}
            if isinstance(precision, QuantPolicy):
                rep = accuracy_report(m, p, xc, policy=precision)
                record["accuracy"] = {
                    "rel_err_vs_fp32": rep["rel_err"],
                    "bound": rep["bound"],
                    "quantized_nodes": rep["quantized_nodes"],
                    "fp_nodes": rep["fp_nodes"]}
                record["quant"] = {n: q.label()
                                   for n, q in gp.quant.items()}
            records.append(record)

        # fused vs unfused: the SAME tuned per-node configs, the fusion
        # pass on vs off — the cross-layer fusion delta (DESIGN.md §10)
        gpf = m.graph_plan((1, HW, HW, C))
        gpu = m.graph_plan((1, HW, HW, C), fuse=False)
        fnf = jax.jit(lambda pp, x, gp=gpf, m=m: m.apply(pp, x,
                                                         graph_plan=gp))
        fnu = jax.jit(lambda pp, x, gp=gpu, m=m: m.apply(pp, x,
                                                         graph_plan=gp))
        x = jnp.asarray(rng.normal(size=(1, HW, HW, C)), jnp.float32)
        us_f = time_fn(fnf, p, x, repeats=3, warmup=1)
        us_u = time_fn(fnu, p, x, repeats=3, warmup=1)
        rows.append(csv_row(
            f"graph/{m.name}_fusion_delta", us_f,
            f"dtype=float32 unfused_us={us_u:.1f} "
            f"speedup={us_u / max(us_f, 1e-9):.2f}x "
            f"fused_nodes={len(gpf.fused)} "
            f"ir_nodes={len(gpf.graph)}v{len(gpu.graph)}"))
        records.append({"name": f"graph/{m.name}_fusion_delta",
                        "config": f"{m.name} b1 {HW}x{HW}x{C}",
                        "dtype": "float32",
                        "us": us_f, "unfused_us": us_u,
                        "speedup": us_u / max(us_f, 1e-9),
                        "fused": dict(gpf.fused),
                        "ir_nodes_fused": len(gpf.graph),
                        "ir_nodes_unfused": len(gpu.graph)})

        # the same fused-vs-unfused delta for the quantized graph: int8
        # specs carry their fusions in the cache key, so this exercises
        # the fused-int8 path (requantize -> fp32 add/relu epilogue)
        qpol = QuantPolicy()
        gqf = m.graph_plan((1, HW, HW, C), precision=qpol)
        gqu = m.graph_plan((1, HW, HW, C), precision=qpol, fuse=False)
        fqf = jax.jit(lambda pp, x, gp=gqf, m=m: m.apply(pp, x,
                                                         graph_plan=gp))
        fqu = jax.jit(lambda pp, x, gp=gqu, m=m: m.apply(pp, x,
                                                         graph_plan=gp))
        us_qf = time_fn(fqf, p, x, repeats=3, warmup=1)
        us_qu = time_fn(fqu, p, x, repeats=3, warmup=1)
        qdtype = "+".join(sorted({n.spec.dtype
                                  for n in gqf.graph.conv_nodes}))
        rows.append(csv_row(
            f"graph/{m.name}_fusion_delta_int8", us_qf,
            f"dtype={qdtype} unfused_us={us_qu:.1f} "
            f"speedup={us_qu / max(us_qf, 1e-9):.2f}x "
            f"fused_nodes={len(gqf.fused)}"))
        records.append({"name": f"graph/{m.name}_fusion_delta_int8",
                        "config": f"{m.name} b1 {HW}x{HW}x{C}",
                        "dtype": qdtype,
                        "us": us_qf, "unfused_us": us_qu,
                        "speedup": us_qu / max(us_qf, 1e-9),
                        "fused": dict(gqf.fused),
                        "quant": {n: q.label()
                                  for n, q in gqf.quant.items()}})
    # ---- async front end: one frontend, two resolutions, deadlines ----
    # the configs/serve.py smoke deployment: resnet_like at 32x32 and
    # 16x16, continuous batching, double-buffered dispatch, per-request
    # latency telemetry written into the bench JSON
    m = resnet_like()
    p = m.init(jax.random.PRNGKey(0))
    fe = AsyncServeFrontend(
        m, p, SMOKE_FRONTEND.geometry_map(),
        max_wait_ms=SMOKE_FRONTEND.max_wait_ms,
        default_deadline_ms=SMOKE_FRONTEND.default_deadline_ms,
        pipeline_depth=SMOKE_FRONTEND.pipeline_depth)
    fe.warmup()
    traffic = ([(4, 32), (2, 16), (4, 32), (1, 16), (4, 32), (2, 16),
                (4, 32), (3, 32)] if quick else
               [(4, 32), (2, 16), (4, 32), (1, 16), (4, 32), (2, 16),
                (4, 32), (3, 32), (4, 32), (2, 16), (4, 32), (1, 32),
                (4, 32), (2, 16), (4, 32), (5, 32)])
    import time as _t
    t0 = _t.perf_counter()
    for i, (n, hw) in enumerate(traffic):
        fe.submit(ServeRequest(
            rid=i, images=rng.normal(size=(n, hw, hw, 3)).astype(np.float32),
            # mixed-deadline traffic: explicit SLO on half the requests,
            # the frontend default on the rest
            deadline_ms=None if i % 2 else
            SMOKE_FRONTEND.default_deadline_ms / 2))
    done = fe.run()
    total_us = (_t.perf_counter() - t0) * 1e6
    st = fe.stats()
    assert all(r.status == "served" for r in done), st

    # overlap evidence: the pipelined steady-state interval between
    # same-program batches vs that program's transfer and compute timed
    # SEPARATELY (serialized) — interval < transfer + compute means the
    # double buffer really hid the host->device copy behind compute
    shape0, b0 = (32, 32, 3), 4
    progs = fe.programs[shape0]
    xb = rng.normal(size=(b0,) + shape0).astype(progs.input_dtype())
    ts = []
    for _ in range(5):
        t1 = _t.perf_counter()
        jax.block_until_ready(jax.device_put(xb))
        ts.append(_t.perf_counter() - t1)
    transfer_us = float(np.median(ts) * 1e6)
    xd = jax.device_put(xb)
    compute_us = time_fn(progs.fn(b0), p, xd, repeats=5, warmup=1)
    sb = [b for b in fe.telemetry.batches
          if b.geometry == "32x32x3" and b.bucket == b0]
    intervals = [(nxt.harvest_t - prev.harvest_t) * 1e6
                 for prev, nxt in zip(sb, sb[1:]) if nxt.overlapped]
    interval_us = float(np.median(intervals)) if intervals else None
    overlap = {"batch_interval_us": interval_us,
               "transfer_us": transfer_us, "compute_us": compute_us,
               "serialized_us": transfer_us + compute_us,
               "overlapped_batches": st["overlapped_batches"],
               "batches": st["batches"]}
    rows.append(csv_row(
        "graph/async_frontend", total_us,
        f"dtype=float32 reqs={st['served']} images={st['images']} "
        f"resolutions={len(st['geometries'])} "
        f"misses={st['deadline_misses']} "
        f"overlap={st['overlapped_batches']}/{st['batches']} "
        f"p50_total_ms={st['latency_ms']['total']['p50']:.2f} "
        f"p99_total_ms={st['latency_ms']['total']['p99']:.2f}"))
    if interval_us is not None:
        rows.append(csv_row(
            "graph/async_frontend_overlap", interval_us,
            f"dtype=float32 steady-state batch interval vs "
            f"serialized transfer+compute="
            f"{transfer_us + compute_us:.1f}us "
            f"(transfer={transfer_us:.1f} compute={compute_us:.1f})"))
    records.append({"name": "graph/async_frontend",
                    "config": (f"resnet_like geometries="
                               f"{st['geometries']} "
                               f"max_wait_ms={SMOKE_FRONTEND.max_wait_ms} "
                               f"slo_ms="
                               f"{SMOKE_FRONTEND.default_deadline_ms}"),
                    "dtype": "float32", "us": total_us,
                    "requests": st["requests"], "served": st["served"],
                    "images": st["images"],
                    "padded_slots": st["padded_slots"],
                    "resolutions": st["geometries"],
                    "batches_by_program": st["batches_by_program"],
                    "deadline_misses": st["deadline_misses"],
                    "late_served": st["late_served"],
                    "latency_ms": st["latency_ms"],
                    "overlap": overlap})

    path = write_json("graph_serve", records)
    rows.append(f"# wrote {path}")
    return rows
