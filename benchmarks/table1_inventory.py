"""Paper Table 1: inventory of the five CNNs' convolution configurations.

Derived (no timing): distinct-config counts and filter-size fractions,
reconstructed from the public architecture definitions (the paper's exact
list lives in its ref [11]; counts match Table 1, GoogleNet within a few
— see EXPERIMENTS.md §Paper-repro).
"""
from __future__ import annotations

from repro.configs import cnn_paper as cp
from benchmarks.common import csv_row


def run(quick=True):
    rows = ["# table1_inventory: name,us_per_call,derived"]
    paper_counts = {"googlenet": 42, "squeezenet": 21, "alexnet": 4,
                    "resnet50": 12, "vgg19": 9}
    for net, convs in cp.NETWORKS.items():
        fr = cp.filter_size_fractions(net)
        frs = " ".join(f"{k}x{k}:{v*100:.1f}%" for k, v in fr.items())
        rows.append(csv_row(
            f"table1/{net}", 0.0,
            f"distinct={len(convs)} paper={paper_counts[net]} {frs}"))
    rows.append(csv_row("table1/total_distinct", 0.0,
                        f"{len(cp.all_distinct())} (paper >600 incl. "
                        f"batch-size sweep {len(cp.all_distinct()) * len(cp.BATCH_SIZES)})"))
    return rows
