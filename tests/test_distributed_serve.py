"""Multi-device sharded serving (serve/distributed.py).

The device-count matrix runs in subprocesses (forced host-platform
device counts must be set before jax initialises) and checks the
subsystem's three load-bearing properties: sharded results are
BITWISE-identical to the single-device engine, every request is served
exactly once, and params are replicated once — a warm serve round runs
clean under ``jax.transfer_guard("disallow")``.  The in-process tests
cover the deterministic per-host ownership rule.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.serve.distributed import owned_geometries

GEOMS = {(8, 8, 3): (2,), (12, 12, 3): (2,), (16, 16, 3): (1, 4)}


# ---------------------------------------------------------------------------
# deterministic per-host geometry ownership

def test_owned_geometries_partition_is_total_and_deterministic():
    """Across any process count: every geometry has exactly one owner,
    the union covers the whole table, and each process derives the same
    answer from the same config (no coordination)."""
    for pc in (1, 2, 3, 5):
        parts = [owned_geometries(GEOMS, i, pc) for i in range(pc)]
        combined = {}
        for p in parts:
            for shape, buckets in p.items():
                assert shape not in combined       # exactly one owner
                combined[shape] = buckets
        assert combined == {s: tuple(b) for s, b in GEOMS.items()}
        assert parts == [owned_geometries(GEOMS, i, pc) for i in range(pc)]
    # more hosts than geometries: the extras own nothing and idle
    assert owned_geometries(GEOMS, 4, 5) == {}
    with pytest.raises(ValueError, match="process_index"):
        owned_geometries(GEOMS, 3, 3)


def test_dispatcher_owns_its_slice_and_rejects_the_rest():
    import jax

    from repro.models.cnn import tiny_cnn
    from repro.serve import ServeRequest, ShardedServeDispatcher

    model = tiny_cnn()
    params = model.init(jax.random.PRNGKey(0))
    disp = ShardedServeDispatcher(model, params, GEOMS,
                                  process_index=0, process_count=2)
    assert disp.owned == owned_geometries(GEOMS, 0, 2)
    unowned = next(s for s in GEOMS if s not in disp.owned)
    with pytest.raises(ValueError, match="not owned by process 0/2"):
        disp.submit(ServeRequest(rid=0, images=np.zeros(
            (1,) + unowned, np.float32)))
    # an owner-less process idles: no frontend, empty serving surface
    idle = ShardedServeDispatcher(model, params, {(8, 8, 3): (2,)},
                                  process_index=1, process_count=2)
    assert idle.geometries == () and idle.frontend is None
    assert idle.poll() == [] and idle.run() == [] and idle.warmup() == {}
    st = idle.stats()
    assert st["requests"] == 0 and st["process_index"] == 1
    assert len(st["partitions"]) == idle.n_devices


# ---------------------------------------------------------------------------
# the device-count matrix (subprocess per forced device count)

_WORKER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import hashlib, json
import jax, numpy as np
from repro.configs.serve import DIST_SMOKE
from repro.models.cnn import tiny_cnn
from repro.serve import (CnnServeEngine, ImageRequest, ServeRequest,
                         ShardedServeDispatcher)

shape = (8, 8, 3)
buckets = DIST_SMOKE.geometry_map()[shape]
model = tiny_cnn()
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(7)
sizes = [1, 2, 3, 2] * 6                      # 24 requests, 48 images
imgs = [rng.standard_normal((k,) + shape).astype(np.float32)
        for k in sizes]

disp = ShardedServeDispatcher(model, params, {{shape: buckets}},
                              process_index=0, process_count=1)
assert disp.n_devices == {n}
# device-count-aware buckets: global = per-shard x mesh size
assert disp.global_buckets(shape) == tuple(b * {n} for b in buckets)
disp.warmup()
for i, x in enumerate(imgs):                  # warm serving round
    disp.submit(ServeRequest(rid=1000 + i, images=x))
disp.run()

# replicated-once params: a WARM round makes no implicit transfer —
# inputs move via explicit put, outputs via explicit device_get, and
# the replicated param tree is reused by reference
with jax.transfer_guard("disallow"):
    for i, x in enumerate(imgs):
        disp.submit(ServeRequest(rid=i, images=x))
    done = disp.run()

done.sort(key=lambda r: r.rid)
assert [r.rid for r in done] == list(range(len(imgs)))   # exactly once
assert all(r.status == "served" for r in done)
assert all(r.out.shape == (x.shape[0], 3)
           for r, x in zip(done, imgs))
digest = hashlib.sha1(
    np.concatenate([r.out for r in done]).tobytes()).hexdigest()

st = disp.stats()
assert len(st["partitions"]) == {n}
shard = st["sharding"]
assert shard["devices"] == {n}
assert sum(shard["per_device_units"]) == 2 * sum(sizes)  # warm + guarded

# the single-device reference: synchronous unsharded engine, same
# params, same images, same (per-shard) buckets
eng = CnnServeEngine(model, params, shape, buckets=buckets)
for i, x in enumerate(imgs):
    eng.submit(ImageRequest(rid=i, images=x))
ref = sorted(eng.run(), key=lambda r: r.rid)
ref_digest = hashlib.sha1(
    np.concatenate([r.out for r in ref]).tobytes()).hexdigest()
print("DIST_OK", json.dumps({{"digest": digest, "ref": ref_digest}}))
"""


def test_device_count_matrix_bitwise_identical_and_exactly_once():
    """{1, 2, 4} forced host devices: every count serves the same
    request set exactly once, bitwise-identical to the single-device
    engine — and identical ACROSS device counts."""
    digests = set()
    for n in (1, 2, 4):
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run(
            [sys.executable, "-c", _WORKER.format(n=n)], cwd=Path.cwd(),
            env=env, capture_output=True, text=True, timeout=560)
        assert "DIST_OK" in out.stdout, (
            f"devices={n}:\n{out.stderr[-3000:]}")
        payload = json.loads(out.stdout.split("DIST_OK", 1)[1])
        assert payload["digest"] == payload["ref"], (
            f"devices={n}: sharded outputs differ from the "
            f"single-device engine")
        digests.add(payload["digest"])
    assert len(digests) == 1, f"digest drift across device counts: {digests}"
