import os

import numpy as np
import pytest


@pytest.fixture(scope="session", autouse=True)
def _hermetic_cache_dir(tmp_path_factory):
    """Point the persisted plan/autotune caches at a per-session temp
    dir so the suite is hermetic: entries left in ``~/.cache/repro`` by
    earlier runs (or other code versions) can't leak into
    cache-behaviour assertions like ``source == "graph_cache"``."""
    d = tmp_path_factory.mktemp("repro_cache")
    old = os.environ.get("REPRO_CACHE_DIR")
    os.environ["REPRO_CACHE_DIR"] = str(d)
    # drop anything already read from the old dir during collection
    from repro.core import autotune, graph
    from repro.quant import calibrate
    autotune.clear_cache()
    graph.clear_cache()
    calibrate.clear_cache()
    yield
    if old is None:
        os.environ.pop("REPRO_CACHE_DIR", None)
    else:
        os.environ["REPRO_CACHE_DIR"] = old


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _reset_plan_counters():
    """Zero the observability counters between tests so assertions like
    ``PLAN_STATS["resolutions"] == 0`` never see another test's work."""
    from repro.core import autotune, convspec
    convspec.reset_plan_stats()
    autotune.reset_measure_stats()
    yield
