import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _reset_plan_counters():
    """Zero the observability counters between tests so assertions like
    ``PLAN_STATS["resolutions"] == 0`` never see another test's work."""
    from repro.core import autotune, convspec
    convspec.reset_plan_stats()
    autotune.reset_measure_stats()
    yield
