"""Serving engine behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, smoke_variant
from repro.models import lm
from repro.serve import Request, ServeEngine


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-1.3b"])
def test_engine_serves_all_requests(arch, rng):
    cfg = smoke_variant(get_config(arch))
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=3, max_len=32)
    for i in range(7):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, 8).astype(np.int32), max_new_tokens=4))
    done = eng.run(prompt_len=8)
    assert len(done) == 7
    assert all(len(r.out_tokens) == 4 for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.out_tokens)


def test_engine_greedy_matches_manual_decode(rng):
    """Engine output for a single request == hand-rolled greedy loop."""
    cfg = smoke_variant(get_config("qwen2-1.5b"))
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)

    eng = ServeEngine(cfg, params, slots=1, max_len=32)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
    done = eng.run(prompt_len=8)

    # manual greedy
    cache = lm.init_cache(cfg, 1, 32)
    logits, cache = lm.prefill(params, cfg,
                               {"tokens": jnp.asarray(prompt[None])}, cache)
    toks = []
    cur = int(np.asarray(logits[0, -1, :cfg.vocab_size]).argmax())
    toks.append(cur)
    off = 8
    for _ in range(4):
        lg, cache = lm.decode_step(
            params, cfg, {"tokens": jnp.asarray([[cur]], jnp.int32)},
            cache, off)
        cur = int(np.asarray(lg[0, 0, :cfg.vocab_size]).argmax())
        toks.append(cur)
        off += 1
    assert done[0].out_tokens == toks


def test_engine_respects_max_len(rng):
    cfg = smoke_variant(get_config("qwen2-1.5b"))
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=2, max_len=12)
    eng.submit(Request(rid=0, prompt=rng.integers(0, 255, 8).astype(np.int32),
                       max_new_tokens=100))
    done = eng.run(prompt_len=8)
    assert len(done) == 1
    assert len(done[0].out_tokens) <= 12 - 8 + 1
