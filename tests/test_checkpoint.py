"""Fault-tolerance tests: atomicity, integrity, resume, elastic re-mesh,
gradient compression."""
import json
import os
import shutil
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


def _tree(rng):
    return {"a": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
            "nested": {"b": jnp.asarray(rng.integers(0, 9, (3,)), jnp.int32),
                       "c": jnp.asarray(rng.normal(size=(5,)),
                                        jnp.float32).astype(jnp.bfloat16)}}


def test_roundtrip(tmp_path, rng):
    t = _tree(rng)
    ckpt.save_checkpoint(tmp_path, 7, t)
    like = jax.eval_shape(lambda: t)
    r = ckpt.restore_checkpoint(tmp_path, 7, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_and_gc(tmp_path, rng):
    t = _tree(rng)
    for s in (1, 2, 3, 4, 5):
        ckpt.save_checkpoint(tmp_path, s, t, keep=3)
    assert ckpt.latest_step(tmp_path) == 5
    assert ckpt.latest_steps(tmp_path) == [3, 4, 5]     # older GC'd


def test_corruption_detected(tmp_path, rng):
    t = _tree(rng)
    d = ckpt.save_checkpoint(tmp_path, 1, t)
    manifest = json.loads((d / "manifest.json").read_text())
    fname = manifest["arrays"]["a"]["file"]
    arr = np.load(d / fname)
    arr[0, 0] += 1.0                                   # silent bit-flip
    np.save(d / fname, arr)
    like = jax.eval_shape(lambda: t)
    with pytest.raises(IOError, match="corruption"):
        ckpt.restore_checkpoint(tmp_path, 1, like)


def test_incomplete_checkpoint_ignored(tmp_path, rng):
    """A crash mid-write (tmp dir, no manifest) must be invisible."""
    t = _tree(rng)
    ckpt.save_checkpoint(tmp_path, 3, t)
    (tmp_path / "step_9.tmp").mkdir()                  # simulated crash
    (tmp_path / "step_11").mkdir()                     # no manifest
    assert ckpt.latest_step(tmp_path) == 3


def test_async_checkpoint(tmp_path, rng):
    t = _tree(rng)
    th = ckpt.save_checkpoint(tmp_path, 2, t, async_=True)
    th.join()
    assert ckpt.latest_step(tmp_path) == 2


def test_trainer_resume(tmp_path, rng):
    """Kill-and-restart: the second trainer must resume, not restart."""
    from repro.configs.base import get_config, smoke_variant
    from repro.data import SyntheticLMData
    from repro.train.trainer import Trainer, TrainConfig
    import dataclasses

    cfg = dataclasses.replace(smoke_variant(get_config("qwen2-1.5b")),
                              grad_accum=1)
    data = SyntheticLMData(cfg.vocab_size, 4, 16)
    tcfg = TrainConfig(steps=4, ckpt_every=2, ckpt_dir=str(tmp_path),
                       ckpt_async=False, log_every=100)
    t1 = Trainer(cfg, tcfg, data)
    t1.run()
    assert ckpt.latest_step(tmp_path) == 4

    tcfg2 = TrainConfig(steps=6, ckpt_every=2, ckpt_dir=str(tmp_path),
                        ckpt_async=False, log_every=100)
    t2 = Trainer(cfg, tcfg2, data)
    start = t2.resume_or_init()
    assert start == 4                                   # resumed, not 0
    t2.state = None
    t2.run()
    assert ckpt.latest_step(tmp_path) == 6


def test_elastic_remesh_restore(tmp_path):
    """Save on mesh (4,2), restore onto mesh (2,2) with different device
    count — runs in a subprocess with 8 forced host devices."""
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint as ckpt

meshA = jax.make_mesh((4, 2), ("data", "model"))
x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
xs = jax.device_put(x, NamedSharding(meshA, P("data", "model")))
ckpt.save_checkpoint(r"{tmp_path}", 1, {{"w": xs}})

meshB = jax.make_mesh((2, 2), ("data", "model"))
like = jax.eval_shape(lambda: {{"w": x}})
shard = {{"w": NamedSharding(meshB, P("model", "data"))}}
r = ckpt.restore_checkpoint(r"{tmp_path}", 1, like, shardings=shard)
np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(x))
assert r["w"].sharding.mesh.shape["data"] == 2
print("ELASTIC_OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], cwd=Path.cwd(),
                         env=env, capture_output=True, text=True, timeout=300)
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]


# ---------------------------------------------------------------------------
# gradient compression

def test_quantize_roundtrip_error_bounded(rng):
    from repro.dist import compress as C
    x = jnp.asarray(rng.normal(size=(1000,)) * 3, jnp.float32)
    q, scale, shape = C.quantize(x)
    deq = C.dequantize(q, scale, shape)
    # int8 symmetric: per-block error <= scale/2 = max|block|/254
    err = np.abs(np.asarray(deq - x))
    assert err.max() <= float(jnp.abs(x).max()) / 254 + 1e-6


def test_error_feedback_converges(rng):
    """Sum of EF-compressed gradients converges to the true sum: the
    residual never leaks, it is re-applied next step."""
    from repro.dist import compress as C
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32) * 0.01
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(50):
        (q, s, sh), err = C.quantize_with_feedback(g, err)
        total = total + C.dequantize(q, s, sh)
    drift = np.abs(np.asarray(total - 50 * g)).max()
    # residual is bounded by one quantization step, not 50
    assert drift <= float(jnp.abs(g).max()) / 100


def test_compressed_psum_matches_psum(tmp_path):
    """shard_map int8 psum over a 4-device axis ~= exact psum."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.dist import compress as C

mesh = jax.make_mesh((4,), ("pod",))
x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 64)), jnp.float32)
err0 = jnp.zeros((4, 64), jnp.float32)

from jax.experimental.shard_map import shard_map

@partial(shard_map, mesh=mesh, in_specs=(P("pod"), P("pod")),
         out_specs=(P("pod"), P("pod")))
def f(xs, es):
    out, new_e = C.compressed_psum(xs[0], "pod", es[0])
    return out[None], new_e[None]

got, _ = f(x, err0)
want = x.sum(0)
rel = np.abs(np.asarray(got[0] - want)).max() / np.abs(np.asarray(want)).max()
assert rel < 0.02, rel
print("PSUM_OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], cwd=Path.cwd(),
                         env=env, capture_output=True, text=True, timeout=300)
    assert "PSUM_OK" in out.stdout, out.stderr[-2000:]
