"""Packing/telemetry invariants shared by BOTH schedulers.

Property-tests the contracts the drain engine (CnnServeEngine) and the
continuous-batching frontend (AsyncServeFrontend) must agree on, over
randomized request mixes and bucket sets:

* every submitted image is served exactly once (no drops, no double
  serves — outputs match a per-image marker exactly);
* every dispatched batch pads fewer slots than the smallest bucket
  (padding only ever rides the smallest bucket's tail);
* telemetry percentile rollups are monotone (p99 >= p95 >= p50).

One tiny model/jit-program set is shared across examples (module-scoped
engines would hide packing bugs, so engines are fresh per example — but
the model's plan memo and jit caches keep re-runs cheap).
"""
import jax
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # deterministic fallback; see _hypothesis_compat
    from _hypothesis_compat import given, settings, strategies as st

from repro.models.cnn import SimpleCNN
from repro.serve.cnn import CnnServeEngine, ImageRequest
from repro.serve.frontend import SERVED, AsyncServeFrontend, ServeRequest
from repro.serve.telemetry import rollup_percentiles

HW = 6
_MODEL = SimpleCNN([(1, 1, 3, 1)], num_classes=4)
_PARAMS = _MODEL.init(jax.random.PRNGKey(0))

# head weights are fixed; a per-image constant input yields a distinct,
# reproducible output row per marker value, so "served exactly once with
# the right result" is checkable without a conv reference
_BUCKET_SETS = [(1,), (2,), (1, 3), (2, 4), (1, 2, 4)]


def _marked_images(sizes):
    """Requests whose image i of request r is constant-filled with a
    unique marker — output rows identify their source image."""
    reqs, marker = [], 1
    for rid, n in enumerate(sizes):
        imgs = np.zeros((n, HW, HW, 3), np.float32)
        for i in range(n):
            imgs[i] = marker
            marker += 1
        reqs.append((rid, imgs))
    return reqs


def _expected_row(marker):
    x = np.full((1, HW, HW, 3), float(marker), np.float32)
    return np.asarray(_MODEL.apply(_PARAMS, x))[0]


def _check_served_exactly_once(reqs):
    for rid, imgs, out in reqs:
        assert out is not None, f"request {rid} never served"
        assert out.shape[0] == imgs.shape[0]
        for i in range(imgs.shape[0]):
            np.testing.assert_allclose(
                out[i], _expected_row(imgs[i, 0, 0, 0]),
                rtol=3e-4, atol=3e-4,
                err_msg=f"request {rid} image {i} wrong/missing result")


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(_BUCKET_SETS),
       st.tuples(*[st.integers(1, 5)] * 3))
def test_drain_engine_packing_invariants(buckets, sizes):
    eng = CnnServeEngine(_MODEL, _PARAMS, (HW, HW, 3), buckets=buckets)
    reqs = [ImageRequest(rid=rid, images=imgs)
            for rid, imgs in _marked_images(sizes)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == len(sizes)
    _check_served_exactly_once([(r.rid, r.images, r.out) for r in reqs])
    assert eng.stats["images"] == sum(sizes)
    assert eng.stats["requests"] == len(sizes)
    # padding only rides the smallest bucket's final short batch, so
    # padded slots per batch (and in a drain: per run) < smallest bucket
    assert eng.stats["padded_slots"] < min(buckets)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(_BUCKET_SETS),
       st.tuples(*[st.integers(1, 5)] * 3),
       st.sampled_from([1, 2, 3]))
def test_frontend_packing_invariants(buckets, sizes, depth):
    fe = AsyncServeFrontend(_MODEL, _PARAMS, {(HW, HW, 3): buckets},
                            pipeline_depth=depth)
    reqs = [ServeRequest(rid=rid, images=imgs)
            for rid, imgs in _marked_images(sizes)]
    for r in reqs:
        fe.submit(r)
    done = fe.run()
    assert sorted(r.rid for r in done) == list(range(len(sizes)))
    assert all(r.status == SERVED for r in done)
    _check_served_exactly_once([(r.rid, r.images, r.out) for r in reqs])
    st_ = fe.stats()
    assert st_["images"] == sum(sizes)
    # the frontend invariant is per BATCH, visible in the batch traces
    for b in fe.telemetry.batches:
        assert b.padded < min(buckets), (b.bucket, b.padded)
        assert b.units + b.padded == b.bucket
    assert st_["max_inflight"] <= depth
    lat = st_["latency_ms"]
    for stage, ps in lat.items():
        assert ps["p50"] <= ps["p95"] <= ps["p99"], stage


@settings(max_examples=20, deadline=None)
@given(st.tuples(*[st.integers(0, 10_000)] * 7))
def test_rollup_percentiles_monotone(samples):
    """p99 >= p95 >= p50 for ANY latency series (interpolated
    percentiles are monotone in q by construction)."""
    xs = [s / 7.0 for s in samples]
    ps = rollup_percentiles(xs)
    assert ps["p50"] <= ps["p95"] <= ps["p99"]
    assert min(xs) <= ps["p50"] and ps["p99"] <= max(xs)


def test_rollup_percentiles_rejects_empty():
    with pytest.raises(ValueError):
        rollup_percentiles([])
