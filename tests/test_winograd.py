"""Numeric tests for the reference Winograd decompositions.

core/winograd.py is the one home of the F(2x2,3x3) and F(4x4,3x3)
transform matrices — the pure-jnp reference path here and the Pallas
winograd_pallas executor both read them.  These tests pin both
variants against ``lax.conv_general_dilated`` so the module can't rot
silently.  F(4,3) has larger transform constants (powers up to 8 in
A^T), so its numeric bound is looser than F(2,3)'s — the same
trade-off the executor's tuning space exposes as the ``m`` config dim.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.winograd import conv_winograd, matrices


def _conv_ref(x, w, padding):
    if padding == "same":
        pads = ((1, 1), (1, 1))
    elif padding == "valid":
        pads = ((0, 0), (0, 0))
    else:
        ph, pw = ((padding, padding) if isinstance(padding, int)
                  else padding)
        pads = ((ph, ph), (pw, pw))
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32), (1, 1), pads,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


@pytest.mark.parametrize("padding", ["same", "valid", 0, 1, 2, (2, 1)])
@pytest.mark.parametrize("shape", [(1, 8, 8, 3, 4), (2, 9, 7, 5, 6)])
def test_winograd_matches_lax(rng, padding, shape):
    n, h, w_, c, m = shape
    x = jnp.asarray(rng.standard_normal((n, h, w_, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, c, m)), jnp.float32)
    got = conv_winograd(x, w, padding=padding)
    want = _conv_ref(x, w, padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_winograd_bf16_inputs(rng):
    """bf16 operands: the transform computes in fp32 (the module casts
    up), so the result tracks the fp32 reference within bf16 input
    rounding."""
    xf = jnp.asarray(rng.standard_normal((1, 10, 10, 4)), jnp.float32)
    wf = jnp.asarray(rng.standard_normal((3, 3, 4, 8)), jnp.float32)
    x, w = xf.astype(jnp.bfloat16), wf.astype(jnp.bfloat16)
    got = conv_winograd(x, w, padding="same")
    want = _conv_ref(x.astype(jnp.float32), w.astype(jnp.float32), "same")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("padding", ["same", "valid", (2, 1)])
@pytest.mark.parametrize("shape", [(1, 8, 8, 3, 4), (2, 9, 7, 5, 6),
                                   (1, 13, 13, 8, 8)])
def test_winograd_f4_matches_lax(rng, padding, shape):
    """F(4x4,3x3): 4x multiply savings, 6x6 transforms.  The inverse
    transform's +-8 coefficients amplify rounding, so the bound is an
    order looser than F(2,3)'s — still well inside 1e-3 relative."""
    n, h, w_, c, m = shape
    x = jnp.asarray(rng.standard_normal((n, h, w_, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, c, m)), jnp.float32)
    got = conv_winograd(x, w, padding=padding, m=4)
    want = _conv_ref(x, w, padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_winograd_f4_agrees_with_f2(rng):
    """Both variants compute the same convolution; they differ only in
    tile size and rounding."""
    x = jnp.asarray(rng.standard_normal((1, 10, 10, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 4, 8)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(conv_winograd(x, w, m=2)),
        np.asarray(conv_winograd(x, w, m=4)), rtol=2e-3, atol=2e-3)


def test_matrices_shapes_and_invalid_m():
    for m in (2, 4):
        bt, g, at = matrices(m)
        assert bt.shape == (m + 2, m + 2)
        assert g.shape == (m + 2, 3)
        assert at.shape == (m, m + 2)
    with pytest.raises(ValueError, match="got m=3"):
        matrices(3)
    with pytest.raises(ValueError, match="got m=3"):
        conv_winograd(jnp.zeros((1, 8, 8, 3), jnp.float32),
                      jnp.zeros((3, 3, 3, 4), jnp.float32), m=3)


def test_winograd_rejects_non3x3_and_stride():
    x = jnp.zeros((1, 8, 8, 3), jnp.float32)
    with pytest.raises(AssertionError, match="3x3"):
        conv_winograd(x, jnp.zeros((5, 5, 3, 4), jnp.float32))
    with pytest.raises(AssertionError, match="stride"):
        conv_winograd(x, jnp.zeros((3, 3, 3, 4), jnp.float32), stride=2)
