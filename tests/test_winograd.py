"""Numeric tests for the dormant F(2x2, 3x3) Winograd path.

core/winograd.py predates the executor registry's pallas-backed
winograd and stays as the reference decomposition; these tests pin it
against ``lax.conv_general_dilated`` so the module can't rot silently.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.winograd import conv_winograd


def _conv_ref(x, w, padding):
    if padding == "same":
        pads = ((1, 1), (1, 1))
    elif padding == "valid":
        pads = ((0, 0), (0, 0))
    else:
        ph, pw = ((padding, padding) if isinstance(padding, int)
                  else padding)
        pads = ((ph, ph), (pw, pw))
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32), (1, 1), pads,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


@pytest.mark.parametrize("padding", ["same", "valid", 0, 1, 2, (2, 1)])
@pytest.mark.parametrize("shape", [(1, 8, 8, 3, 4), (2, 9, 7, 5, 6)])
def test_winograd_matches_lax(rng, padding, shape):
    n, h, w_, c, m = shape
    x = jnp.asarray(rng.standard_normal((n, h, w_, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, c, m)), jnp.float32)
    got = conv_winograd(x, w, padding=padding)
    want = _conv_ref(x, w, padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_winograd_bf16_inputs(rng):
    """bf16 operands: the transform computes in fp32 (the module casts
    up), so the result tracks the fp32 reference within bf16 input
    rounding."""
    xf = jnp.asarray(rng.standard_normal((1, 10, 10, 4)), jnp.float32)
    wf = jnp.asarray(rng.standard_normal((3, 3, 4, 8)), jnp.float32)
    x, w = xf.astype(jnp.bfloat16), wf.astype(jnp.bfloat16)
    got = conv_winograd(x, w, padding="same")
    want = _conv_ref(x.astype(jnp.float32), w.astype(jnp.float32), "same")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=3e-2, atol=3e-2)


def test_winograd_rejects_non3x3_and_stride():
    x = jnp.zeros((1, 8, 8, 3), jnp.float32)
    with pytest.raises(AssertionError, match="3x3"):
        conv_winograd(x, jnp.zeros((5, 5, 3, 4), jnp.float32))
    with pytest.raises(AssertionError, match="stride"):
        conv_winograd(x, jnp.zeros((3, 3, 3, 4), jnp.float32), stride=2)
