"""Integration: losses decrease, schedules behave, data is deterministic."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, smoke_variant
from repro.data import SyntheticLMData
from repro.train.trainer import Trainer, TrainConfig


class _FixedData(SyntheticLMData):
    """Constant batch: the memorization workload — loss must collapse."""

    def batch_at(self, step):
        return super().batch_at(0)


def test_loss_decreases_dense(tmp_path):
    cfg = dataclasses.replace(smoke_variant(get_config("qwen2-1.5b")),
                              grad_accum=1)
    data = _FixedData(cfg.vocab_size, 8, 32, seed=3)
    tcfg = TrainConfig(steps=30, ckpt_every=1000, ckpt_dir=str(tmp_path),
                       peak_lr=3e-3, log_every=1000)
    tr = Trainer(cfg, tcfg, data)
    tr.run()
    first = np.mean([m["loss"] for m in tr.metrics_log[:5]])
    last = np.mean([m["loss"] for m in tr.metrics_log[-5:]])
    assert last < first - 0.5, (first, last)


def test_loss_decreases_ssm(tmp_path):
    cfg = dataclasses.replace(smoke_variant(get_config("mamba2-1.3b")),
                              grad_accum=1)
    data = _FixedData(cfg.vocab_size, 8, 32, seed=3)
    tcfg = TrainConfig(steps=30, ckpt_every=1000, ckpt_dir=str(tmp_path),
                       peak_lr=3e-3, log_every=1000)
    tr = Trainer(cfg, tcfg, data)
    tr.run()
    first = np.mean([m["loss"] for m in tr.metrics_log[:5]])
    last = np.mean([m["loss"] for m in tr.metrics_log[-5:]])
    assert last < first - 0.5, (first, last)


def test_grad_accum_equivalence(rng):
    """accum=4 must match accum=1 up to accumulation-order rounding."""
    from repro.launch.steps import make_train_step
    from repro.models import lm
    from repro.optim import adamw_init
    cfg1 = dataclasses.replace(smoke_variant(get_config("qwen2-1.5b")),
                               grad_accum=1)
    cfg4 = dataclasses.replace(cfg1, grad_accum=4)
    params = jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        lm.init_lm(cfg1, jax.random.PRNGKey(0)))
    state = {"params": params, "opt": adamw_init(params),
             "step": jnp.zeros((), jnp.int32)}
    batch = {"tokens": jnp.asarray(rng.integers(0, 256, (8, 16)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 256, (8, 16)), jnp.int32)}
    s1, m1 = jax.jit(make_train_step(cfg1))(state, batch)
    state2 = {"params": params, "opt": adamw_init(params),
              "step": jnp.zeros((), jnp.int32)}
    s4, m4 = jax.jit(make_train_step(cfg4))(state2, batch)
    assert abs(m1["loss"] - m4["loss"]) < 2e-3
    a = jax.tree.leaves(s1["params"])[0]
    b = jax.tree.leaves(s4["params"])[0]
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=5e-3)


def test_cosine_schedule():
    from repro.optim import cosine_schedule
    lr0 = float(cosine_schedule(0, peak_lr=1.0, warmup_steps=10,
                                total_steps=100))
    lr_peak = float(cosine_schedule(10, peak_lr=1.0, warmup_steps=10,
                                    total_steps=100))
    lr_end = float(cosine_schedule(99, peak_lr=1.0, warmup_steps=10,
                                   total_steps=100, min_ratio=0.1))
    assert lr0 < 0.2 and abs(lr_peak - 1.0) < 1e-5 and lr_end < 0.15


def test_data_pipeline_deterministic_and_learnable():
    d1 = SyntheticLMData(100, 4, 32, seed=7)
    d2 = SyntheticLMData(100, 4, 32, seed=7)
    b1, b2 = d1.batch_at(42), d2.batch_at(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    # markov structure: successor distribution is peaked (learnable)
    b = d1.batch_at(0)
    _, counts = np.unique(b["tokens"], return_counts=True)
    assert counts.max() > 2


def test_straggler_detection(tmp_path, monkeypatch):
    import time as time_mod
    cfg = dataclasses.replace(smoke_variant(get_config("qwen2-1.5b")),
                              grad_accum=1)
    data = SyntheticLMData(cfg.vocab_size, 2, 8)
    tcfg = TrainConfig(steps=10, ckpt_every=1000, ckpt_dir=str(tmp_path),
                       straggler_factor=2.0, log_every=1000)
    tr = Trainer(cfg, tcfg, data)
    orig = tr.step_fn
    calls = {"n": 0}

    def slow_step(state, batch):
        calls["n"] += 1
        if calls["n"] == 9:
            time_mod.sleep(1.0)          # simulated straggler step
        return orig(state, batch)

    tr.step_fn = slow_step
    tr.run()
    assert any("straggler_detected" in m for m in tr.metrics_log)


def test_grad_compression_trains(tmp_path):
    """int8+EF compressed gradients still drive the loss down."""
    cfg = dataclasses.replace(smoke_variant(get_config("qwen2-1.5b")),
                              grad_accum=1)
    data = _FixedData(cfg.vocab_size, 8, 32, seed=3)
    tcfg = TrainConfig(steps=25, ckpt_every=1000, ckpt_dir=str(tmp_path),
                       peak_lr=3e-3, log_every=1000, grad_compression=True)
    tr = Trainer(cfg, tcfg, data)
    tr.run()
    first = np.mean([m["loss"] for m in tr.metrics_log[:5]])
    last = np.mean([m["loss"] for m in tr.metrics_log[-5:]])
    assert last < first - 0.5, (first, last)
