"""Property-based tests (hypothesis) for the cuConv algorithm family."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import assume, given, settings, strategies as st
except ImportError:   # deterministic fallback; see _hypothesis_compat
    from _hypothesis_compat import assume, given, settings, strategies as st

from repro.core import cuconv as cc
from repro.core.executors import ALGORITHMS
from repro.kernels import ref

conv_shapes = st.tuples(
    st.integers(1, 3),                 # N
    st.integers(3, 14),                # H (=W)
    st.sampled_from([1, 3, 5]),        # K
    st.integers(1, 24),                # C
    st.integers(1, 16),                # M
    st.integers(1, 2),                 # stride
)


def _mk(shape_tuple, seed=0):
    N, H, K, C, M, s = shape_tuple
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(N, H, H, C)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, C, M)), jnp.float32)
    return x, w, s


@settings(max_examples=40, deadline=None)
@given(conv_shapes, st.integers(0, 2**31 - 1))
def test_all_algorithms_agree(shape_tuple, seed):
    """Every cuConv variant equals the library convolution (same padding)."""
    x, w, s = _mk(shape_tuple, seed)
    if s > 1 and x.shape[1] < w.shape[0]:
        s = 1
    want = cc.conv_lax(x, w, s, "same")
    for name in ["im2col", "cuconv_two_stage", "cuconv"]:
        got = ALGORITHMS[name](x, w, s, "same")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-4, err_msg=name)


@settings(max_examples=20, deadline=None)
@given(conv_shapes, st.integers(0, 2**31 - 1))
def test_stage_decomposition_property(shape_tuple, seed):
    """The paper's core identity: conv == sum over taps of shifted 1x1
    channel contractions (stage2(stage1(x)) == conv), for any K."""
    x, w, _ = _mk(shape_tuple, seed)
    assume(x.shape[1] >= w.shape[0])       # valid padding needs H >= K
    temps = cc.cuconv_stage1(x, w, 1, "valid")
    K2 = w.shape[0] * w.shape[1]
    assert temps.shape[0] == K2, "one temporary matrix per filter tap"
    got = cc.cuconv_stage2(temps)
    want = cc.conv_lax(x, w, 1, "valid")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(2, 10), st.integers(1, 16),
       st.integers(1, 8), st.integers(0, 2**31 - 1))
def test_1x1_is_single_gemm(N, H, C, M, seed):
    """1x1 filters: stage-1 output IS the convolution (paper's fast path)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(N, H, H, C)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(1, 1, C, M)), jnp.float32)
    temps = cc.cuconv_stage1(x, w, 1, "valid")
    assert temps.shape[0] == 1
    want = cc.conv_lax(x, w, 1, "valid")
    np.testing.assert_allclose(np.asarray(temps[0]), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 2), st.integers(4, 10), st.sampled_from([3, 5]),
       st.integers(1, 12), st.integers(1, 8), st.integers(0, 2**31 - 1))
def test_linearity_in_filters(N, H, K, C, M, seed):
    """Convolution is linear in w: conv(x, a*w1 + w2) == a*conv(x,w1)+conv(x,w2)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(N, H, H, C)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(K, K, C, M)), jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(K, K, C, M)), jnp.float32)
    a = 1.7
    lhs = cc.conv_cuconv(x, a * w1 + w2, 1, "same")
    rhs = a * cc.conv_cuconv(x, w1, 1, "same") + cc.conv_cuconv(
        x, w2, 1, "same")
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-3, atol=1e-3)


def test_autotune_heuristic_regions():
    from repro.core.autotune import select_algorithm
    # 1x1: always cuConv (the paper's winning region)
    assert select_algorithm((1, 7, 7, 832), (1, 1, 832, 256)) == "cuconv"
    # batch-1 small spatial: cuConv
    assert select_algorithm((1, 7, 7, 192), (3, 3, 192, 384)) == "cuconv"
    # large 3x3: Winograd's region in the paper
    assert select_algorithm((64, 56, 56, 128), (3, 3, 128, 128)) == "winograd"
    # stride != 1 -> library
    assert select_algorithm((1, 7, 7, 64), (3, 3, 64, 64), stride=2) == "lax"


def test_measured_autotune_runs(rng):
    from repro.core.autotune import measure_algorithm
    x = jnp.asarray(rng.normal(size=(1, 7, 7, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(1, 1, 32, 16)), jnp.float32)
    best = measure_algorithm(x, w, repeats=1)
    assert best in ALGORITHMS


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 3), st.integers(3, 14), st.integers(1, 16),
       st.integers(1, 12), st.sampled_from(["same", "valid"]),
       st.integers(0, 2**31 - 1))
def test_winograd_equals_direct(N, H, C, M, pad, seed):
    """The Winograd baseline (the paper's main competitor) == library conv."""
    from repro.core.winograd import conv_winograd
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(N, H, H, C)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, C, M)), jnp.float32)
    got = conv_winograd(x, w, 1, pad)
    want = cc.conv_lax(x, w, 1, pad)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_winograd_filter_transform_identity():
    """A delta filter transforms to a tensor whose A^T m A collapses back
    to the identity convolution (sanity of the transform matrices)."""
    from repro.core.winograd import conv_winograd
    w = jnp.zeros((3, 3, 1, 1)).at[1, 1, 0, 0].set(1.0)   # center tap
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1, 8, 8, 1)),
                    jnp.float32)
    got = conv_winograd(x, w, 1, "same")
    np.testing.assert_allclose(np.asarray(got), np.asarray(x),
                               rtol=1e-4, atol=1e-4)


def test_winograd_fallback_non3x3():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 7, 7, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(5, 5, 4, 3)), jnp.float32)
    got = ALGORITHMS["winograd"](x, w, 1, "same")
    want = cc.conv_lax(x, w, 1, "same")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
