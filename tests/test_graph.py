"""Graph plan layer (core/graph.py) + batch-bucketed CNN serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import convspec as cs
from repro.core import cuconv as cc
from repro.core import graph as g
from repro.models.cnn import SimpleCNN, squeezenet_like
from repro.serve.cnn import CnnServeEngine, ImageRequest


@pytest.fixture(autouse=True)
def _hermetic_caches(tmp_path, monkeypatch):
    """Point both persisted plan stores (autotune.json, graphplans.json)
    at an empty per-test dir so other runs on this machine can't leak."""
    from repro.core import autotune
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "cache"))
    autotune.clear_cache()
    g.clear_cache()
    yield
    autotune.clear_cache()
    g.clear_cache()


TINY = [(3, 3, 8, 2), (1, 1, 4, 1), (3, 3, 6, 1)]


def _lax_model_ref(model, params, x):
    """Unbatched-library reference for the whole model (conv -> bias ->
    relu per block, GAP, head)."""
    y = x
    for p, (kh, kw, co, s) in zip(params["convs"], model.spec):
        y = jax.nn.relu(cc.conv_lax(y, p["w"], s, "same") + p["b"])
    return y.mean(axis=(1, 2)) @ params["head"]


# ---------------------------------------------------------------------------
# ConvGraph

def test_graph_chain_geometry():
    gph = g.ConvGraph.chain(TINY, (2, 16, 16, 3))
    assert len(gph) == 3
    assert gph.in_shape == (2, 16, 16, 3)
    assert gph.nodes[0].out_shape == (2, 8, 8, 8)     # stride-2 halves H/W
    assert gph.nodes[1].in_shape == gph.nodes[0].out_shape
    assert gph.out_shape == (2, 8, 8, 6)
    assert all(s.epilogue == "bias_relu" for s in gph.nodes)
    sig = gph.signature()
    assert sig == g.ConvGraph.chain(TINY, (2, 16, 16, 3)).signature()
    assert sig != g.ConvGraph.chain(TINY, (1, 16, 16, 3)).signature()


def test_graph_rejects_broken_chain():
    a = cs.ConvSpec((1, 8, 8, 3), (3, 3, 3, 4), (1, 1), (1, 1))
    b = cs.ConvSpec((1, 4, 4, 4), (1, 1, 4, 2))
    with pytest.raises(ValueError):
        g.ConvGraph((a, b))


# ---------------------------------------------------------------------------
# GraphPlan resolution, cache, explain

def test_graph_cache_roundtrip_zero_replans():
    """A warm process reconstructs the program from graphplans.json with
    ZERO per-node plan() resolutions."""
    gph = g.ConvGraph.chain(TINY, (1, 16, 16, 3))
    gp1 = g.plan_graph(gph)
    assert gp1.source == "resolved"
    assert g._STORE.path().exists()
    g.clear_cache()                       # simulate a fresh process
    cs.reset_plan_stats()
    gp2 = g.plan_graph(gph)
    assert gp2.source == "graph_cache"
    assert cs.PLAN_STATS["resolutions"] == 0
    assert ([p.algorithm for p in gp2.node_plans]
            == [p.algorithm for p in gp1.node_plans])
    assert all(p.source == "graph_cache" for p in gp2.node_plans)


def test_plan_graph_use_cache_false_touches_no_store():
    gph = g.ConvGraph.chain(TINY, (1, 16, 16, 3))
    gp = g.plan_graph(gph, use_cache=False)
    assert gp.source == "resolved"
    assert g._STORE.get(g._graph_key(gph, gp.backend)) is None


def test_forced_graph_bypasses_cache():
    gph = g.ConvGraph.chain(TINY, (1, 16, 16, 3))
    g.plan_graph(gph)                     # persist the auto choice
    gp = g.plan_graph(gph, force="lax")
    assert gp.source == "forced"
    assert all(p.algorithm == "lax" for p in gp.node_plans)
    # the forced run must not have clobbered the persisted auto entry
    g.clear_cache()
    assert g.plan_graph(gph).source == "graph_cache"


def test_explain_lists_every_node():
    gph = g.ConvGraph.chain(TINY, (1, 16, 16, 3))
    gp = g.plan_graph(gph)
    txt = gp.explain()
    assert gph.signature() in txt
    assert len(txt.splitlines()) == len(gph) + 1
    for p in gp.node_plans:
        assert p.algorithm in txt


def test_measured_winner_invalidates_graph_cache_entry():
    """plan()'s measured > heuristic precedence survives the graph layer:
    a winner recorded AFTER the graph entry was persisted forces a
    re-resolve instead of serving the stale heuristic program forever."""
    from repro.core import autotune
    gph = g.ConvGraph.chain([(1, 1, 4, 1)], (1, 6, 6, 3))
    gp1 = g.plan_graph(gph)
    assert gp1.source == "resolved"
    other = next(a for a in ("lax", "im2col")
                 if a != gp1.node_plans[0].algorithm)
    autotune.record_best(gph.nodes[0], gp1.backend, other)
    g.clear_cache()
    gp2 = g.plan_graph(gph)
    assert gp2.source == "resolved"          # stale entry was dropped
    assert gp2.node_plans[0].algorithm == other
    assert gp2.node_plans[0].source == "measured"
    g.clear_cache()                          # re-persisted entry now agrees
    assert g.plan_graph(gph).source == "graph_cache"


def test_warmup_measure_rejects_foreign_backend():
    """Measuring on the default backend but recording under another
    backend's key would silently discard the sweep — refuse instead."""
    other = "tpu" if jax.default_backend() != "tpu" else "cpu"
    gp = g.plan_graph(g.ConvGraph.chain([(1, 1, 4, 1)], (1, 6, 6, 3)),
                      backend=other)
    with pytest.raises(ValueError):
        gp.warmup(measure=True)


def test_warmup_measure_records_winners():
    gph = g.ConvGraph.chain([(1, 1, 4, 1)], (1, 6, 6, 3))
    gp = g.plan_graph(gph)
    stats = gp.warmup(measure=True, repeats=1)
    assert len(stats["nodes"]) == 1
    assert stats["nodes"][0]["source"] == "measured"
    from repro.core import autotune
    assert autotune.cached_best(gph.nodes[0]) is not None


# ---------------------------------------------------------------------------
# SimpleCNN over GraphPlan

def test_planned_once_then_zero_replans(rng):
    """Acceptance: warmup() then N inference calls triggers zero
    additional plan() resolutions, and outputs match the lax reference."""
    model = squeezenet_like()
    params = model.init(jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(1, 32, 32, 3)), jnp.float32)
    gp = model.graph_plan((1, 32, 32, 3))
    gp.warmup()
    cs.reset_plan_stats()
    for _ in range(3):
        y = model.apply(params, x)        # eager: re-enters apply each time
    assert cs.PLAN_STATS["resolutions"] == 0
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(_lax_model_ref(model, params, x)),
        rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("algorithm", ["auto", "lax", "cuconv", "im2col"])
def test_model_apply_matches_reference(rng, algorithm):
    model = SimpleCNN(TINY, num_classes=5)
    params = model.init(jax.random.PRNGKey(1))
    x = jnp.asarray(rng.normal(size=(2, 16, 16, 3)), jnp.float32)
    y = jax.jit(lambda p, xx: model.apply(p, xx, algorithm=algorithm))(
        params, x)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(_lax_model_ref(model, params, x)),
        rtol=3e-4, atol=3e-4, err_msg=algorithm)


# ---------------------------------------------------------------------------
# CnnServeEngine

def test_serve_mixed_stream_buckets_and_outputs(rng):
    """Acceptance: a mixed-size request stream is served through at most
    the configured buckets, outputs matching the unbatched lax reference."""
    model = SimpleCNN(TINY, num_classes=4)
    params = model.init(jax.random.PRNGKey(0))
    eng = CnnServeEngine(model, params, (16, 16, 3), buckets=(1, 2, 4))
    eng.warmup()
    sizes = [1, 3, 2, 5, 1]
    reqs = [ImageRequest(rid=i, images=rng.normal(
        size=(n, 16, 16, 3)).astype(np.float32))
        for i, n in enumerate(sizes)]
    for r in reqs:
        eng.submit(r)
    cs.reset_plan_stats()
    done = eng.run()
    assert cs.PLAN_STATS["resolutions"] == 0    # warm engine: no re-plans
    assert len(done) == len(sizes) and all(r.done for r in done)
    assert set(eng.compiled_buckets) <= set(eng.buckets)
    assert eng.stats["images"] == sum(sizes)
    for r in reqs:
        for i in range(r.images.shape[0]):
            ref = _lax_model_ref(model, params,
                                 jnp.asarray(r.images[i:i + 1]))
            np.testing.assert_allclose(r.out[i], np.asarray(ref)[0],
                                       rtol=3e-4, atol=3e-4,
                                       err_msg=f"req {r.rid} image {i}")


def test_serve_pads_short_tail(rng):
    model = SimpleCNN([(1, 1, 4, 1)], num_classes=3)
    params = model.init(jax.random.PRNGKey(0))
    eng = CnnServeEngine(model, params, (8, 8, 3), buckets=(4,))
    eng.submit(ImageRequest(                       # single (H, W, C) image
        rid=0, images=rng.normal(size=(8, 8, 3)).astype(np.float32)))
    done = eng.run()
    assert done[0].out.shape == (1, 3)
    assert eng.stats["padded_slots"] == 3
    assert eng.compiled_buckets == (4,)
    ref = _lax_model_ref(model, params, jnp.asarray(done[0].images))
    np.testing.assert_allclose(done[0].out, np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


def test_serve_measured_warmup_rebuilds_programs(rng):
    """warmup(measure=True) after programs were already compiled must not
    keep serving the stale traces: every bucket program is rebuilt."""
    model = SimpleCNN([(1, 1, 4, 1)], num_classes=3)
    params = model.init(jax.random.PRNGKey(0))
    eng = CnnServeEngine(model, params, (6, 6, 3), buckets=(1, 2))
    eng.warmup()
    fns_before = dict(eng._fns)
    eng.warmup(measure=True)
    assert set(eng._fns) == set(fns_before)
    assert all(eng._fns[b] is not fns_before[b] for b in fns_before)
    eng.submit(ImageRequest(rid=0, images=rng.normal(
        size=(2, 6, 6, 3)).astype(np.float32)))
    done = eng.run()
    ref = _lax_model_ref(model, params, jnp.asarray(done[0].images))
    np.testing.assert_allclose(done[0].out, np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


def test_serve_retry_after_mid_drain_failure(rng):
    """The retry contract run()'s comment promises, pinned: a bucket
    program that raises mid-drain leaves engine.queue intact, and a
    retried run() serves every image exactly once (outputs rewrite
    idempotently)."""
    model = SimpleCNN([(1, 1, 4, 1)], num_classes=3)
    params = model.init(jax.random.PRNGKey(0))
    eng = CnnServeEngine(model, params, (8, 8, 3), buckets=(2,))
    eng.warmup()
    reqs = [ImageRequest(rid=i, images=rng.normal(
        size=(n, 8, 8, 3)).astype(np.float32))
        for i, n in enumerate([2, 3])]          # 5 units -> 3 batches
    for r in reqs:
        eng.submit(r)
    real, calls = eng._fns[2], {"n": 0}

    def boom(params, xb):
        calls["n"] += 1
        if calls["n"] == 2:                     # fail on the SECOND batch
            raise RuntimeError("injected mid-drain failure")
        return real(params, xb)

    eng._fns[2] = boom
    with pytest.raises(RuntimeError, match="mid-drain"):
        eng.run()
    assert eng.queue == reqs                    # nothing lost, FIFO order
    assert not any(r.done for r in reqs)
    eng._fns[2] = real                          # "transient" fault clears
    done = eng.run()
    assert eng.queue == [] and [r.rid for r in done] == [0, 1]
    assert all(r.done for r in done)
    assert eng.stats["requests"] == 2
    for r in reqs:                              # exactly once: every row
        assert r.out.shape == (r.images.shape[0], 3)
        for i in range(r.images.shape[0]):
            ref = _lax_model_ref(model, params,
                                 jnp.asarray(r.images[i:i + 1]))
            np.testing.assert_allclose(r.out[i], np.asarray(ref)[0],
                                       rtol=3e-4, atol=3e-4,
                                       err_msg=f"req {r.rid} image {i}")


def test_serve_rejects_wrong_geometry(rng):
    model = SimpleCNN([(1, 1, 4, 1)], num_classes=3)
    params = model.init(jax.random.PRNGKey(0))
    eng = CnnServeEngine(model, params, (8, 8, 3))
    with pytest.raises(ValueError):
        eng.submit(ImageRequest(
            rid=0, images=rng.normal(size=(4, 4, 3)).astype(np.float32)))
    with pytest.raises(ValueError):
        CnnServeEngine(model, params, (8, 8, 3), buckets=())
