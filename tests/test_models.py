"""Per-arch smoke tests + decode/prefill consistency + SSD correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_config, list_archs, smoke_variant
from repro.models import lm

ALL_ARCHS = list_archs()


def _batch(cfg, B, S, rng, labels=True):
    batch = {}
    if cfg.input_mode == "tokens":
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    else:
        batch["embeds"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)),
                                      jnp.float32).astype(jnp.bfloat16)
    if cfg.mrope_sections:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32), (3, B, S))
    if labels:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    return batch


def test_all_10_archs_registered():
    assert len(ALL_ARCHS) == 10
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch, rng):
    """Reduced same-family config: one forward + one train step on CPU;
    asserts shapes and no NaNs (per-arch smoke requirement)."""
    cfg = smoke_variant(get_config(arch))
    cfg = dataclasses.replace(cfg, grad_accum=2)
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    B, S = 4, 16
    batch = _batch(cfg, B, S, rng)
    logits, _, aux = lm.lm_forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    from repro.launch.steps import make_train_step, state_specs
    from repro.optim import adamw_init
    state = {"params": params, "opt": adamw_init(params),
             "step": jnp.zeros((), jnp.int32)}
    step = jax.jit(make_train_step(cfg))
    new_state, metrics = step(state, batch)
    assert int(new_state["step"]) == 1
    assert np.isfinite(metrics["loss"])
    assert np.isfinite(metrics["grad_norm"])


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "qwen3-14b", "musicgen-large",
                                  "deepseek-v2-lite-16b", "deepseek-moe-16b",
                                  "jamba-v0.1-52b", "mamba2-1.3b",
                                  "qwen2-vl-2b"])
def test_decode_matches_full_forward(arch, rng):
    """prefill+decode must reproduce teacher-forced logits (f32 cache)."""
    cfg = smoke_variant(get_config(arch))
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        lm.init_lm(cfg, jax.random.PRNGKey(1)))
    B, S, MAX = 2, 12, 20
    full = _batch(cfg, B, S + 4, rng, labels=False)
    full_logits, _, _ = lm.lm_forward(params, cfg, full, mode="train")

    def cut(b, sl):
        out = {}
        for k, v in b.items():
            if k == "positions":
                out[k] = v[:, :, sl]
            else:
                out[k] = v[:, sl]
        return out

    cache = lm.init_cache(cfg, B, MAX, kv_dtype=jnp.float32)
    pl_logits, cache = lm.prefill(params, cfg, cut(full, slice(0, S)), cache)
    np.testing.assert_allclose(
        np.asarray(pl_logits[:, -1], np.float32),
        np.asarray(full_logits[:, S - 1], np.float32), rtol=2e-4, atol=2e-4)
    off = S
    for t in range(4):
        lg, cache = lm.decode_step(params, cfg,
                                   cut(full, slice(S + t, S + t + 1)),
                                   cache, off)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32),
            np.asarray(full_logits[:, S + t], np.float32),
            rtol=2e-4, atol=2e-4)
        off += 1


def test_chunked_attention_equals_exact(rng):
    from repro.nn.attention import chunked_attention, exact_attention
    B, S, H, D = 2, 50, 3, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    for chunk in (8, 16, 64):
        got = chunked_attention(q, k, v, chunk=chunk)
        want = exact_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
    # unrolled twin (dry-run probe path) must agree too
    got_u = chunked_attention(q, k, v, chunk=16, unroll=True)
    np.testing.assert_allclose(np.asarray(got_u),
                               np.asarray(exact_attention(q, k, v)),
                               rtol=2e-5, atol=2e-5)


def test_ssd_chunked_equals_sequential(rng):
    from repro.nn.mamba import ssd_chunked, ssd_decode_step
    b, l, h, p, g, n = 2, 16, 3, 4, 1, 5
    x = jnp.asarray(rng.normal(size=(b, l, h, p)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(b, l, h))) * 0.5, jnp.float32)
    A = -jnp.asarray(np.abs(rng.normal(size=(h,))) * 0.5, jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, l, g, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, l, g, n)), jnp.float32)
    y_chunk, fs = ssd_chunked(x, dt, A, B, C, chunk=4)
    st = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(l):
        y, st = ssd_decode_step(st, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_chunk),
                               np.asarray(jnp.stack(ys, 1)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(st),
                               rtol=1e-4, atol=1e-4)


def test_mrope_sections_shift_independently(rng):
    """M-RoPE: changing only the h-section positions must change the output
    only through the h rotary slots."""
    from repro.nn import layers as L
    B, S, H, D = 1, 6, 2, 16
    x = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    base = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (3, B, S))
    shifted = base.at[1].add(5)          # only h-axis positions move
    y0 = L.apply_rope(x, base, sections=(4, 2, 2))
    y1 = L.apply_rope(x, shifted, sections=(4, 2, 2))
    d = np.asarray(jnp.abs(y0 - y1).sum(axis=(0, 1, 2)))
    half = D // 2
    # t-section slots (0:4 and half:half+4) untouched
    assert d[:4].sum() == 0 and d[half:half + 4].sum() == 0
    # h-section slots (4:6, half+4:half+6) changed
    assert d[4:6].sum() > 0 and d[half + 4:half + 6].sum() > 0


def test_num_params_analytic_matches_actual():
    for arch in ["qwen2-1.5b", "mamba2-1.3b", "deepseek-moe-16b"]:
        cfg = smoke_variant(get_config(arch))
        params = lm.init_lm(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        assert actual == cfg.num_params(), arch


def test_chunked_ce_matches_simple(rng):
    """§Perf lever: fused head+CE must be numerically identical."""
    from repro.models.lm import cross_entropy, cross_entropy_chunked
    B, S, D, Vp, V = 2, 19, 16, 64, 50
    h = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(D, Vp)), jnp.float32)
    y = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    a = cross_entropy(h @ w, y, V)
    for kwargs in (dict(chunk=8), dict(chunk=8, unroll=True), dict(chunk=32)):
        b = cross_entropy_chunked(h, w, y, V, **kwargs)
        np.testing.assert_allclose(float(a), float(b), rtol=1e-6)
    ga = jax.grad(lambda hh: cross_entropy(hh @ w, y, V))(h)
    gb = jax.grad(lambda hh: cross_entropy_chunked(hh, w, y, V, chunk=8))(h)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gb),
                               rtol=1e-5, atol=1e-6)


def test_train_loss_ce_impl_equivalence(rng):
    """cfg.ce_impl='chunked' end-to-end == 'simple' (loss + grads)."""
    cfg = smoke_variant(get_config("qwen2-1.5b"))
    cfg_c = dataclasses.replace(cfg, ce_impl="chunked")
    params = jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        lm.init_lm(cfg, jax.random.PRNGKey(0)))
    batch = _batch(cfg, 2, 16, rng)
    l1, _ = lm.train_loss(params, cfg, batch)
    l2, _ = lm.train_loss(params, cfg_c, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_remat_policies_same_loss(rng):
    cfg0 = smoke_variant(get_config("qwen2-1.5b"))
    params = lm.init_lm(cfg0, jax.random.PRNGKey(0))
    batch = _batch(cfg0, 2, 16, rng)         # one batch for all policies
    for remat in ("none", "full", "dots"):
        cfg = dataclasses.replace(cfg0, remat=remat)
        loss, _ = lm.train_loss(params, cfg, batch)
        g = jax.grad(lambda p: lm.train_loss(p, cfg, batch)[0])(params)
        assert np.isfinite(float(loss))
        if remat == "none":
            base = float(loss)
        else:
            # remat reorders bf16 fusions; equality is up to rounding
            np.testing.assert_allclose(float(loss), base, rtol=2e-3)


def test_bf16_score_attention_close(rng):
    """attn_score_dtype=bf16 (§Perf memory lever) stays within bf16 tol."""
    from repro.nn.attention import chunked_attention, exact_attention
    B, S, H, D = 2, 64, 3, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    want = exact_attention(q, k, v)
    got = chunked_attention(q, k, v, chunk=16, score_dtype=jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_ssm_chunk_invariance(rng):
    """ssd chunk size is an execution detail, not a semantic one."""
    cfg = smoke_variant(get_config("mamba2-1.3b"))
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(rng.integers(0, 255, (2, 24)), jnp.int32)}
    outs = []
    for chunk in (16, 32, 256):
        c = dataclasses.replace(cfg, ssm_chunk=chunk)
        lg, _, _ = lm.lm_forward(params, c, batch)
        outs.append(np.asarray(lg, np.float32))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-2, atol=2e-2)
