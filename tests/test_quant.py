"""Int8 quantization subsystem: calibration persistence, QuantPolicy
gating, the int8 executor's numerics, and quantized serving end to end."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune
from repro.core import convspec as cs
from repro.core import executors as ex
from repro.core.graph import PrecisionPolicy
from repro.models import cnn as M
from repro.quant import calibrate as cal
from repro.quant import symmetric
from repro.quant.accuracy import DEFAULT_BOUND, assert_accuracy
from repro.quant.policy import QuantPolicy


def _sample_batch(rng, batch=4, shape=(32, 32, 3)):
    return np.asarray(rng.standard_normal((batch,) + shape), np.float32)


def _tiny_model():
    """Two eligible convs + head, per-node params (GraphModel, not
    SimpleCNN, so ``GraphPlan.run`` can drive it directly)."""
    from repro.core.graph import GraphBuilder

    def build(in_shape, dtype):
        b = GraphBuilder(in_shape, dtype)
        y = b.conv("c0", "input", 3, 6)
        y = b.conv("c1", y, 1, 8)
        y = b.gap("gap", y)
        b.dense("head", y, 3)
        return b.graph()
    return M.GraphModel(build, (8, 8, 3), name="tinyq")


def _calibrated_resnet(rng, batch=4):
    """resnet_like + params + a sample batch, calibrated via warmup."""
    m = M.resnet_like()
    params = m.init(jax.random.PRNGKey(0))
    x = _sample_batch(rng, batch)
    out = m.graph_plan(x.shape).warmup(
        calibrate=cal.Calibrator(x, params))
    return m, params, x, out["calibration"]


# ---------------------------------------------------------------------------
# symmetric helpers (the one core shared with dist/compress.py)

def test_symmetric_roundtrip_and_channel_scales(rng):
    x = jnp.asarray(rng.normal(size=(64,)) * 3.0, jnp.float32)
    scale = symmetric.scale_for(symmetric.abs_max(x))
    back = symmetric.dequantize_int8(
        symmetric.quantize_to_int8(x, scale), scale)
    # each int8 grid cell is `scale` wide: round-to-nearest error <= scale/2
    assert float(jnp.abs(back - x).max()) <= float(scale) / 2 + 1e-7
    # zero range: quantizes to zeros instead of dividing by zero
    z = symmetric.quantize_to_int8(jnp.zeros((4,)), jnp.float32(0.0))
    assert not np.asarray(z).any()
    w = jnp.asarray(rng.normal(size=(3, 3, 6, 5)), jnp.float32)
    scales = symmetric.channel_scales(w)
    assert scales.shape == (5,)
    np.testing.assert_allclose(
        np.asarray(scales),
        np.abs(np.asarray(w)).max(axis=(0, 1, 2)) / 127.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# calibration persistence

def test_calibration_determinism(rng, tmp_path, monkeypatch):
    """Same model + same sample batch -> bit-identical calibration.json,
    however many times the store starts fresh."""
    x = _sample_batch(rng)

    def calibrate_fresh(store):
        monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / store))
        cal.clear_cache()
        m = M.resnet_like()
        params = m.init(jax.random.PRNGKey(0))
        cal.Calibrator(x, params).collect(m.graph_plan(x.shape))
        return json.loads((tmp_path / store / "calibration.json").read_text())

    first, second = calibrate_fresh("a"), calibrate_fresh("b")
    assert first == second
    assert len(first) >= 6          # every resnet_like conv observed


def test_calibration_entry_schema_gate(rng):
    """Unversioned / foreign-schema / malformed entries are dropped on
    read (the autotune.json v2 contract), never misdecoded into scales."""
    m = M.resnet_like()
    g = m.graph((1, 32, 32, 3))
    key = f"{cal.graph_key(g)}/stem"
    for bad in [{"amax": 1.0},                          # unversioned
                {"schema": cal.CALIB_SCHEMA + 1, "amax": 1.0},  # foreign
                {"schema": cal.CALIB_SCHEMA, "amax": "big"},    # malformed
                "not-a-dict"]:
        cal._STORE.put(key, bad)
        assert cal.calibration_entry(g, "stem") is None


def test_calibration_is_batch_and_dtype_normalized(rng):
    """A batch-4 fp32 calibration is found under every bucket size and
    fallback dtype of the same architecture — the property that lets one
    warmup serve all bucket programs."""
    m, params, x, entries = _calibrated_resnet(rng, batch=4)
    assert set(entries) >= {"stem", "b1c1", "b1c2", "b2c1", "b2c2", "b2proj"}
    for in_shape, dtype in [((1, 32, 32, 3), "float32"),
                            ((8, 32, 32, 3), "float32"),
                            ((4, 32, 32, 3), "bfloat16")]:
        g = m.graph(in_shape, dtype=dtype)
        e = cal.calibration_entry(g, "b1c1")
        assert e is not None and e["amax"] > 0
        # the recorded spec is wildcarded too: no batch, no dtype
        assert e["spec"].startswith("n*h") and "-*-" in e["spec"]


def test_recalibration_merges_running_max(rng):
    m = M.resnet_like()
    params = m.init(jax.random.PRNGKey(0))
    small = _sample_batch(rng) * 0.1
    big = _sample_batch(rng) * 10.0
    gp = m.graph_plan(small.shape)
    first = cal.Calibrator(small, params).collect(gp)["stem"]
    merged = cal.Calibrator(big, params).collect(gp)["stem"]
    assert merged["amax"] >= first["amax"]
    assert merged["batches"] == first["batches"] + 1


# ---------------------------------------------------------------------------
# the quantize pass: eligibility gates and provenance

def test_quantize_gates_first_last_and_skip(rng):
    m, params, x, _ = _calibrated_resnet(rng)
    gp = m.graph_plan(x.shape, precision=QuantPolicy())
    quantized = {n for n, q in gp.quant.items() if q.quantized}
    assert quantized == {"b1c1", "b1c2", "b2c1", "b2c2"}
    assert gp.quant["stem"].source == "fp:first"
    assert gp.quant["b2proj"].source == "fp:last"

    gp2 = m.graph_plan(x.shape, precision=QuantPolicy(skip=("b1c1",)))
    assert gp2.quant["b1c1"].source == "fp:skip"
    assert gp2.quant["b1c2"].quantized


def test_uncalibrated_model_stays_fp(rng, tmp_path, monkeypatch):
    """No calibration on record -> every node falls back to fp and the
    quantized plan IS the fp plan, numerically."""
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "empty"))
    cal.clear_cache()
    autotune.clear_cache()
    m = _tiny_model()
    params = m.init(jax.random.PRNGKey(0))
    x = _sample_batch(rng, batch=2, shape=(8, 8, 3))
    gp = m.graph_plan(x.shape,
                      precision=QuantPolicy(skip_first_last=False))
    assert all(q.source == "fp:no-calibration"
               for q in gp.quant.values())
    y_fp = m.graph_plan(x.shape,
                        precision=PrecisionPolicy("float32")).run(x, params)
    np.testing.assert_allclose(np.asarray(gp.run(x, params)),
                               np.asarray(y_fp), rtol=1e-5, atol=1e-5)


def test_stale_calibration_falls_back_until_recalibrated(rng):
    """An entry whose recorded spec no longer matches the node is stale:
    the node serves fp (with provenance saying why) until a fresh
    calibration pass re-resolves it to int8."""
    m, params, x, _ = _calibrated_resnet(rng)
    g = m.graph(x.shape)
    key = f"{cal.graph_key(g)}/b1c1"
    stale = dict(cal._STORE.get(key))
    stale["spec"] = "n*h9w9c9-k9x9m9-s9x9-p9x9-*-none"
    cal._STORE.put(key, stale)

    gq = m.graph_plan(x.shape, precision=QuantPolicy())
    assert gq.quant["b1c1"].source == "fp:stale-calibration"
    assert gq.quant["b1c2"].quantized    # staleness is per-node

    m.graph_plan(x.shape).warmup(calibrate=cal.Calibrator(x, params))
    gq2 = m.graph_plan(x.shape, precision=QuantPolicy())
    assert gq2.quant["b1c1"].quantized


def test_quant_policy_keys_are_distinct():
    keys = {QuantPolicy().key(),
            QuantPolicy(observer="percentile").key(),
            QuantPolicy(skip_first_last=False).key(),
            QuantPolicy(skip=("stem",)).key(),
            PrecisionPolicy("float32").key()}
    assert len(keys) == 5
    with pytest.raises(ValueError):
        QuantPolicy(observer="entropy")


# ---------------------------------------------------------------------------
# the int8 executor

def test_int8_executor_numerics_and_explain(rng):
    x = jnp.asarray(rng.normal(size=(2, 10, 10, 6)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 6, 5)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.normal(size=(5,)), jnp.float32)
    spec = cs.ConvSpec.for_conv(x, w, 1, "same", bias=b, activation="relu")
    q8 = dataclasses.replace(spec, dtype="int8")
    assert "cuconv_int8" in ex.supporting(q8)
    plan = cs.plan(q8)
    assert plan.executor.name == "cuconv_int8"
    assert "int8" in plan.explain() and "int32" in plan.explain()
    y_fp = np.asarray(cs.plan(spec)(x, w, b, None), np.float32)
    y_q = np.asarray(plan(x, w, b, None), np.float32)
    rel = np.abs(y_q - y_fp).max() / (np.abs(y_fp).max() + 1e-12)
    assert rel < DEFAULT_BOUND


def test_int8_per_channel_weight_scales(rng):
    """Output channels with wildly different weight magnitudes each get
    their own scale — a per-tensor weight scale would crush the small
    channels into one or two int8 codes and fail this bound."""
    x = jnp.asarray(rng.normal(size=(1, 8, 8, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 3)), jnp.float32)
    w = w * jnp.asarray([1e-2, 1.0, 1e2])[None, None, None, :]
    spec = cs.ConvSpec.for_conv(x, w, 1, "same")
    y_fp = np.asarray(cs.plan(spec)(x, w, None, None), np.float32)
    y_q = np.asarray(cs.plan(dataclasses.replace(spec, dtype="int8"))(
        x, w, None, None), np.float32)
    for ch in range(3):
        ref = np.abs(y_fp[..., ch]).max()
        assert np.abs(y_q[..., ch] - y_fp[..., ch]).max() / ref < 0.05


# ---------------------------------------------------------------------------
# end to end: quantized graphs, accuracy, serving, tuned replay

def test_quantized_resnet_accuracy_and_explain(rng):
    m, params, x, _ = _calibrated_resnet(rng)
    rep = assert_accuracy(m, params, x)
    assert rep["rel_err"] <= DEFAULT_BOUND
    assert rep["quantized_nodes"] == ["b1c1", "b1c2", "b2c1", "b2c2"]
    text = m.graph_plan(x.shape, precision=QuantPolicy()).explain()
    assert "quant[int8<-calib:absmax]" in text
    # b1c2 is BOTH fused (the residual add rides its epilogue) AND int8
    assert "fused[add" in text


def test_quantized_serving_end_to_end(rng):
    """The tentpole acceptance: a calibrated resnet_like serves int8
    through the existing bucket programs, with the serving dtype
    surfaced per program and output parity with the direct plan."""
    from repro.serve.cnn import CnnServeEngine, ImageRequest
    from repro.serve.frontend import AsyncServeFrontend, ServeRequest
    m, params, x, _ = _calibrated_resnet(rng)
    pol = QuantPolicy()

    eng = CnnServeEngine(m, params, (32, 32, 3), buckets=(1, 4),
                         precision=pol)
    eng.warmup()
    assert all("int8" in d for d in eng.serve_dtypes().values())
    eng.submit(ImageRequest(0, x))
    served = eng.run()
    want = np.asarray(
        m.graph_plan(x.shape, precision=pol).run(x, params))
    np.testing.assert_allclose(served[0].out, want, rtol=1e-5, atol=1e-5)

    fe = AsyncServeFrontend(m, params, {(32, 32, 3): (1, 4)},
                            precision=pol)
    fe.warmup()
    for i in range(3):
        fe.submit(ServeRequest(rid=i, images=x[i:i + 1]))
    fe.run()
    st = fe.stats()
    assert all("int8" in d
               for d in st["serve_dtype_by_program"].values())
    assert sum(c["batches"] for d, c in st["serve_dtypes"].items()
               if "int8" in d) == st["batches"]


def test_int8_tune_full_replays_with_zero_measurement(rng, tmp_path,
                                                      monkeypatch):
    """tune='full' persists dtype-distinct int8 configs; a fresh process
    (fresh model, cleared in-memory caches) replays them without timing
    a single candidate."""
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "tuned"))
    cal.clear_cache()
    autotune.clear_cache()
    from repro.core import graph as G
    G.clear_cache()

    x = _sample_batch(rng, batch=2, shape=(8, 8, 3))
    pol = QuantPolicy(skip_first_last=False)

    m = _tiny_model()
    params = m.init(jax.random.PRNGKey(0))
    m.graph_plan(x.shape).warmup(calibrate=cal.Calibrator(x, params))
    gq = m.graph_plan(x.shape, precision=pol)
    gq.warmup(tune="full")
    tuned = {n: (p.executor.name, p.config)
             for n, p in gq.conv_plans.items()}
    assert all(p.config_source == "measured"
               for p in gq.conv_plans.values())
    assert any(name == "cuconv_int8" for name, _ in tuned.values())
    store = json.loads((tmp_path / "tuned" / "autotune.json").read_text())
    assert any("-int8-" in k for k in store)

    autotune.clear_cache()
    G.clear_cache()
    autotune.reset_measure_stats()
    m2 = _tiny_model()
    g2 = m2.graph_plan(x.shape, precision=pol)
    assert {n: (p.executor.name, p.config)
            for n, p in g2.conv_plans.items()} == tuned
    assert autotune.MEASURE_STATS["timed_calls"] == 0
    np.testing.assert_allclose(np.asarray(g2.run(x, params)),
                               np.asarray(gq.run(x, params)),
                               rtol=1e-5, atol=1e-5)


def test_batch_trace_dtype_rollup():
    """Telemetry aggregates per-dtype batch/image counters and omits the
    section entirely when no dispatcher stamped a dtype."""
    from repro.serve.telemetry import BatchTrace, Telemetry
    t = Telemetry()
    assert "serve_dtypes" not in t.rollup()
    for dtype, units in [("int8", 4), ("int8", 2), ("float32+int8", 1)]:
        t.record_batch(BatchTrace(geometry="32x32x3", bucket=4,
                                  units=units, padded=4 - units,
                                  transfer_t0=0.0, transfer_t1=0.0,
                                  dispatch_t=0.0, dtype=dtype))
    assert t.rollup()["serve_dtypes"] == {
        "int8": {"batches": 2, "images": 6},
        "float32+int8": {"batches": 1, "images": 1}}
