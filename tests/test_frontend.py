"""Async serving front end (serve/frontend.py): continuous batching,
deadline-aware admission, double-buffered dispatch, multi-resolution
routing, and per-request telemetry."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import convspec as cs
from repro.core import cuconv as cc
from repro.models.cnn import SimpleCNN, resnet_like
from repro.serve.frontend import (
    DEADLINE_EXCEEDED, SERVED, AsyncServeFrontend, DeadlineExceeded,
    ServeRequest)


TINY = [(3, 3, 6, 2), (1, 1, 4, 1)]


def _lax_model_ref(model, params, x):
    y = x
    for p, (kh, kw, co, s) in zip(params["convs"], model.spec):
        y = jax.nn.relu(cc.conv_lax(y, p["w"], s, "same") + p["b"])
    return y.mean(axis=(1, 2)) @ params["head"]


class FakeClock:
    """Deterministic injectable clock (seconds); advance in ms."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance_ms(self, ms: float) -> None:
        self.t += ms / 1e3


@pytest.fixture
def tiny():
    model = SimpleCNN(TINY, num_classes=3)
    return model, model.init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# correctness: multi-resolution serving

def test_multi_resolution_mixed_stream_matches_reference(rng, tiny):
    """One frontend, two image geometries: every submitted image is
    served exactly once through its geometry's bucket set, outputs
    matching the unbatched lax reference."""
    model, params = tiny
    fe = AsyncServeFrontend(model, params,
                            {(16, 16, 3): (1, 4), (8, 8, 3): (1, 2)})
    fe.warmup()
    sizes = [(1, 16), (3, 8), (5, 16), (2, 8), (1, 8), (4, 16)]
    reqs = [ServeRequest(rid=i, images=rng.normal(
        size=(n, hw, hw, 3)).astype(np.float32))
        for i, (n, hw) in enumerate(sizes)]
    for r in reqs:
        fe.submit(r)
    cs.reset_plan_stats()
    done = fe.run()
    assert cs.PLAN_STATS["resolutions"] == 0    # warm frontend: no re-plans
    assert sorted(r.rid for r in done) == list(range(len(sizes)))
    assert all(r.status == SERVED and r.done for r in done)
    st = fe.stats()
    assert st["images"] == sum(n for n, _ in sizes)
    assert set(st["geometries"]) == {"16x16x3", "8x8x3"}
    for r in reqs:
        assert r.out.shape == (r.images.shape[0], 3)
        for i in range(r.images.shape[0]):
            ref = _lax_model_ref(model, params,
                                 jnp.asarray(r.images[i:i + 1]))
            np.testing.assert_allclose(r.out[i], np.asarray(ref)[0],
                                       rtol=3e-4, atol=3e-4,
                                       err_msg=f"req {r.rid} image {i}")


def test_rejects_unserved_geometry(rng, tiny):
    model, params = tiny
    fe = AsyncServeFrontend(model, params, {(8, 8, 3): (1,)})
    with pytest.raises(ValueError, match="matches no served geometry"):
        fe.submit(ServeRequest(rid=0, images=rng.normal(
            size=(1, 12, 12, 3)).astype(np.float32)))
    with pytest.raises(ValueError, match="geometries"):
        AsyncServeFrontend(model, params, {})


# ---------------------------------------------------------------------------
# deadline-aware admission

def test_expired_request_rejected_with_typed_result(rng, tiny):
    """A request whose deadline passed before admission comes back
    status=deadline_exceeded with a typed DeadlineExceeded error — not
    silently served — and counts as a deadline miss."""
    model, params = tiny
    clock = FakeClock()
    fe = AsyncServeFrontend(model, params, {(8, 8, 3): (2,)}, clock=clock)
    fe.warmup()
    late = ServeRequest(rid=0, images=rng.normal(
        size=(2, 8, 8, 3)).astype(np.float32), deadline_ms=10.0)
    ok = ServeRequest(rid=1, images=rng.normal(
        size=(1, 8, 8, 3)).astype(np.float32), deadline_ms=10_000.0)
    fe.submit(late)
    fe.submit(ok)
    clock.advance_ms(50.0)          # past late's deadline, within ok's
    done = fe.run()
    by_rid = {r.rid: r for r in done}
    assert by_rid[0].status == DEADLINE_EXCEEDED
    assert isinstance(by_rid[0].error, DeadlineExceeded)
    assert by_rid[0].error.rid == 0
    assert by_rid[0].error.deadline_ms == pytest.approx(10.0)
    assert by_rid[0].error.lateness_ms == pytest.approx(40.0)
    assert by_rid[0].out is None and by_rid[0].done
    assert by_rid[1].status == SERVED and by_rid[1].out is not None
    st = fe.stats()
    assert st["deadline_misses"] == 1
    assert st["served"] == 1


def test_default_deadline_applies_to_unmarked_requests(rng, tiny):
    model, params = tiny
    clock = FakeClock()
    fe = AsyncServeFrontend(model, params, {(8, 8, 3): (1,)},
                            default_deadline_ms=20.0, clock=clock)
    fe.warmup()
    fe.submit(ServeRequest(rid=0, images=rng.normal(
        size=(1, 8, 8, 3)).astype(np.float32)))       # inherits 20ms SLO
    fe.submit(ServeRequest(rid=1, images=rng.normal(
        size=(1, 8, 8, 3)).astype(np.float32), deadline_ms=500.0))
    clock.advance_ms(100.0)
    done = fe.run()
    by_rid = {r.rid: r for r in done}
    assert by_rid[0].status == DEADLINE_EXCEEDED
    assert by_rid[1].status == SERVED


def test_admission_is_edf_within_a_bucket(rng, tiny):
    """Earlier deadlines dispatch first regardless of submit order."""
    model, params = tiny
    fe = AsyncServeFrontend(model, params, {(8, 8, 3): (1,)})
    fe.warmup()
    a = ServeRequest(rid=0, images=rng.normal(
        size=(1, 8, 8, 3)).astype(np.float32), deadline_ms=60_000.0)
    b = ServeRequest(rid=1, images=rng.normal(
        size=(1, 8, 8, 3)).astype(np.float32), deadline_ms=1_000.0)
    c = ServeRequest(rid=2, images=rng.normal(
        size=(1, 8, 8, 3)).astype(np.float32))        # no deadline: last
    for r in (a, c, b):
        fe.submit(r)
    done = fe.run()
    assert [r.rid for r in done] == [1, 0, 2]   # completion order == EDF


def test_committed_request_completes_despite_late_deadline(rng, tiny):
    """A request with units already in flight is never purged — it was
    admitted on time and always completes (late_served accounts it)."""
    model, params = tiny
    clock = FakeClock()
    fe = AsyncServeFrontend(model, params, {(8, 8, 3): (2,)},
                            pipeline_depth=2, clock=clock)
    fe.warmup()
    # 3 units: first batch of 2 dispatches, then the deadline passes
    # before the tail unit is admitted
    r = ServeRequest(rid=0, images=rng.normal(
        size=(3, 8, 8, 3)).astype(np.float32), deadline_ms=10.0)
    fe.submit(r)
    fe.poll()                       # bucket-full: dispatches (r, 0..1)
    clock.advance_ms(50.0)          # deadline passes mid-request
    done = fe.run()
    assert [x.rid for x in done] == [0]
    assert done[0].status == SERVED
    assert fe.stats()["deadline_misses"] == 0
    assert fe.stats()["late_served"] == 1


# ---------------------------------------------------------------------------
# continuous batching: the bucket-full-or-max-wait close policy

def test_short_batch_waits_for_max_wait(rng, tiny):
    model, params = tiny
    clock = FakeClock()
    fe = AsyncServeFrontend(model, params, {(8, 8, 3): (4,)},
                            max_wait_ms=10.0, clock=clock)
    fe.warmup()
    fe.submit(ServeRequest(rid=0, images=rng.normal(
        size=(1, 8, 8, 3)).astype(np.float32)))
    assert fe.poll() == [] and fe.stats()["batches"] == 0   # still waiting
    clock.advance_ms(5.0)
    assert fe.poll() == [] and fe.stats()["batches"] == 0   # not yet
    clock.advance_ms(6.0)                                   # 11ms > 10ms
    fe.poll()
    done = fe.flush()
    assert [r.rid for r in done] == [0] and done[0].status == SERVED
    st = fe.stats()
    assert st["batches"] == 1
    assert st["padded_slots"] == 3      # 1 unit rode the 4-bucket padded


def test_full_bucket_dispatches_without_waiting(rng, tiny):
    model, params = tiny
    clock = FakeClock()
    fe = AsyncServeFrontend(model, params, {(8, 8, 3): (1, 4)},
                            max_wait_ms=10_000.0, clock=clock)
    fe.warmup()
    fe.submit(ServeRequest(rid=0, images=rng.normal(
        size=(4, 8, 8, 3)).astype(np.float32)))
    fe.poll()                       # zero wall-clock has passed
    done = fe.flush()
    assert [r.rid for r in done] == [0]
    assert fe.stats()["batches"] == 1
    assert fe.stats()["padded_slots"] == 0


def test_tight_deadline_closes_batch_before_max_wait(rng, tiny):
    """SLO-aware close: a pending deadline with less slack than the
    remaining close-policy wait dispatches NOW — padded into the
    bucket — instead of expiring in the queue it was told to wait in."""
    model, params = tiny
    clock = FakeClock()
    fe = AsyncServeFrontend(model, params, {(8, 8, 3): (4,)},
                            max_wait_ms=10.0, clock=clock)
    fe.warmup()
    fe.submit(ServeRequest(rid=0, images=rng.normal(
        size=(1, 8, 8, 3)).astype(np.float32), deadline_ms=3.0))
    fe.poll()                       # slack 3ms < 10ms remaining wait
    done = fe.flush()
    assert [r.rid for r in done] == [0] and done[0].status == SERVED
    st = fe.stats()
    assert st["slo_closes"] == 1
    assert st["batches"] == 1 and st["padded_slots"] == 3
    assert st["deadline_misses"] == 0 and st["late_served"] == 0


def test_loose_deadline_still_waits_for_max_wait(rng, tiny):
    """A deadline with plenty of slack does NOT trigger the SLO close —
    the short batch keeps its max_wait patience for more traffic."""
    model, params = tiny
    clock = FakeClock()
    fe = AsyncServeFrontend(model, params, {(8, 8, 3): (4,)},
                            max_wait_ms=10.0, clock=clock)
    fe.warmup()
    fe.submit(ServeRequest(rid=0, images=rng.normal(
        size=(1, 8, 8, 3)).astype(np.float32), deadline_ms=50.0))
    assert fe.poll() == [] and fe.stats()["batches"] == 0
    clock.advance_ms(4.0)           # slack 46ms > 6ms remaining: wait on
    assert fe.poll() == [] and fe.stats()["batches"] == 0
    clock.advance_ms(7.0)           # 11ms > max_wait: the NORMAL close
    fe.poll()
    done = fe.flush()
    assert [r.rid for r in done] == [0] and done[0].status == SERVED
    assert fe.stats()["slo_closes"] == 0


def test_slo_close_margin_adds_service_headroom(rng, tiny):
    """slo_close_margin_ms widens what counts as 'tight': a 12ms
    deadline against 10ms of remaining wait is loose at margin 0 but
    tight at margin 5 (12 <= 10 + 5)."""
    model, params = tiny
    clock = FakeClock()
    fe0 = AsyncServeFrontend(model, params, {(8, 8, 3): (4,)},
                             max_wait_ms=10.0, clock=clock)
    fe0.submit(ServeRequest(rid=0, images=rng.normal(
        size=(1, 8, 8, 3)).astype(np.float32), deadline_ms=12.0))
    assert fe0.poll() == [] and fe0.stats()["slo_closes"] == 0
    fe5 = AsyncServeFrontend(model, params, {(8, 8, 3): (4,)},
                             max_wait_ms=10.0, slo_close_margin_ms=5.0,
                             clock=clock)
    fe5.warmup()
    fe5.submit(ServeRequest(rid=0, images=rng.normal(
        size=(1, 8, 8, 3)).astype(np.float32), deadline_ms=12.0))
    fe5.poll()
    done = fe5.flush()
    assert [r.rid for r in done] == [0] and done[0].status == SERVED
    assert fe5.stats()["slo_closes"] == 1


# ---------------------------------------------------------------------------
# double-buffered dispatch

def test_steady_state_batches_overlap_transfer_with_compute(rng, tiny):
    """With >= 2 batches the pipeline keeps one batch in flight while
    the next is packed + transferred: every steady-state batch is
    flagged overlapped, and the pipeline never exceeds its depth."""
    model, params = tiny
    fe = AsyncServeFrontend(model, params, {(8, 8, 3): (2,)},
                            pipeline_depth=2)
    fe.warmup()
    for i in range(5):
        fe.submit(ServeRequest(rid=i, images=rng.normal(
            size=(2, 8, 8, 3)).astype(np.float32)))
    done = fe.run()
    assert len(done) == 5
    st = fe.stats()
    assert st["batches"] == 5
    # batch 0 has nothing to overlap; every later batch transferred
    # while its predecessor was still in flight
    assert st["overlapped_batches"] == 4
    assert st["max_inflight"] == 2      # depth respected, and reached
    assert st["inflight"] == 0
    for prev, nxt in zip(fe.telemetry.batches, fe.telemetry.batches[1:]):
        assert nxt.overlapped
        assert nxt.transfer_t0 < prev.harvest_t   # the overlap window


def test_pipeline_depth_one_never_overlaps(rng, tiny):
    model, params = tiny
    fe = AsyncServeFrontend(model, params, {(8, 8, 3): (2,)},
                            pipeline_depth=1)
    fe.warmup()
    for i in range(3):
        fe.submit(ServeRequest(rid=i, images=rng.normal(
            size=(2, 8, 8, 3)).astype(np.float32)))
    fe.run()
    st = fe.stats()
    assert st["overlapped_batches"] == 0
    assert st["max_inflight"] == 1


# ---------------------------------------------------------------------------
# telemetry

def test_stats_rollups_are_complete_and_json_ready(rng, tiny):
    model, params = tiny
    fe = AsyncServeFrontend(model, params,
                            {(16, 16, 3): (1, 4), (8, 8, 3): (1, 2)})
    fe.warmup()
    for i, (n, hw) in enumerate([(2, 16), (1, 8), (3, 16), (2, 8)]):
        fe.submit(ServeRequest(rid=i, images=rng.normal(
            size=(n, hw, hw, 3)).astype(np.float32),
            deadline_ms=60_000.0))
    fe.run()
    st = fe.stats()
    json.dumps(st)                      # must be JSON-serializable
    lat = st["latency_ms"]
    assert set(lat) == {"queue", "transfer", "compute", "total"}
    for stage, ps in lat.items():
        assert set(ps) == {"p50", "p95", "p99"}
        assert ps["p50"] <= ps["p95"] <= ps["p99"], stage
        assert all(v >= 0.0 for v in ps.values()), stage
    assert st["requests"] == st["served"] == 4
    assert st["deadline_misses"] == 0
    # per-request accounting: total covers queue+compute for every trace
    for t in fe.telemetry.requests:
        assert t.total_ms >= t.compute_ms
        assert t.total_ms >= t.queue_ms


def test_warmup_compiles_exactly_the_trace_that_serves(rng, tiny):
    """Requests arriving in ANY host dtype are packed to the one
    input_dtype() the warmup dummy compiled — serving triggers zero
    retraces on the warm programs."""
    model, params = tiny
    fe = AsyncServeFrontend(model, params, {(8, 8, 3): (1, 2)})
    fe.warmup()
    fe.submit(ServeRequest(rid=0, images=rng.normal(
        size=(3, 8, 8, 3))))            # float64 host images
    fe.submit(ServeRequest(rid=1, images=rng.normal(
        size=(2, 8, 8, 3)).astype(np.float16)))
    done = fe.run()
    assert all(r.status == SERVED for r in done)
    for b, fn in fe.programs[(8, 8, 3)]._fns.items():
        assert fn._cache_size() == 1, f"bucket {b} retraced while serving"
    for r in done:
        for i in range(r.images.shape[0]):
            ref = _lax_model_ref(model, params, jnp.asarray(
                r.images[i:i + 1], jnp.float32))
            np.testing.assert_allclose(r.out[i], np.asarray(ref)[0],
                                       rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# acceptance: an IR model at two resolutions through one frontend

def test_acceptance_resnet_two_resolutions_zero_misses(rng):
    from repro.configs.serve import SMOKE_FRONTEND
    model = resnet_like(num_classes=4)
    params = model.init(jax.random.PRNGKey(0))
    fe = AsyncServeFrontend(
        model, params, SMOKE_FRONTEND.geometry_map(),
        max_wait_ms=SMOKE_FRONTEND.max_wait_ms,
        default_deadline_ms=SMOKE_FRONTEND.default_deadline_ms,
        pipeline_depth=SMOKE_FRONTEND.pipeline_depth)
    fe.warmup()
    for i, (n, hw) in enumerate([(1, 32), (2, 16), (4, 32), (1, 16),
                                 (3, 32), (2, 16)]):
        fe.submit(ServeRequest(rid=i, images=rng.normal(
            size=(n, hw, hw, 3)).astype(np.float32),
            deadline_ms=None if i % 2 else 30_000.0))
    done = fe.run()
    assert all(r.status == SERVED for r in done)
    st = fe.stats()
    assert st["deadline_misses"] == 0 and st["late_served"] == 0
    assert st["served"] == 6
    assert len(st["batches_by_program"]) >= 2   # both geometries dispatched
    assert st["latency_ms"]["total"]["p99"] >= st["latency_ms"]["total"]["p50"]
