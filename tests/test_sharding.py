"""Sharding-rule unit tests + a real 8-device SPMD train step (subprocess)."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import pytest

from repro.configs.base import get_config, smoke_variant
from repro.dist import sharding as sh
from repro.models import lm


def test_every_param_has_a_rule():
    """logical_axes must cover every leaf of every architecture."""
    from repro.configs.base import list_archs
    for arch in list_archs():
        cfg = smoke_variant(get_config(arch))
        shapes = jax.eval_shape(lambda c=cfg: lm.init_lm(
            c, jax.random.PRNGKey(0)))
        axes = sh.logical_axes(shapes)          # raises if any path unmatched
        n_leaves = len(jax.tree.leaves(shapes))
        n_axes = len(jax.tree.leaves(
            axes, is_leaf=lambda a: isinstance(a, tuple)))
        assert n_leaves == n_axes, arch


def test_no_dead_rules():
    """Every _AXIS_TABLE pattern is the FIRST match for at least one
    real param path across the current architectures.  First-match-wins
    means a rule shadowed by an earlier one (or matching a param no
    arch produces anymore) is dead code — this is the test that forces
    pruning it when a param tree changes."""
    from repro.configs.base import list_archs
    first_matches = set()
    for arch in list_archs():
        cfg = smoke_variant(get_config(arch))
        shapes = jax.eval_shape(lambda c=cfg: lm.init_lm(
            c, jax.random.PRNGKey(0)))
        flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
        for path, _leaf in flat:
            p = sh._path_str(path)
            for i, (pat, _ax) in enumerate(sh._AXIS_TABLE):
                if pat.search(p):
                    first_matches.add(i)
                    break
    dead = [sh._AXIS_TABLE[i][0].pattern
            for i in range(len(sh._AXIS_TABLE)) if i not in first_matches]
    assert not dead, f"dead sharding rules (no param path hits them): {dead}"


def test_param_specs_2d_sharded():
    """Big matrices get both an FSDP ('data') and a TP ('model') axis."""
    cfg = smoke_variant(get_config("qwen2-72b"))
    shapes = jax.eval_shape(lambda: lm.init_lm(cfg, jax.random.PRNGKey(0)))
    rules = sh.make_rules("train", multi_pod=False)
    specs = sh.param_specs(shapes, rules)
    flat = {"/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path): s
            for path, s in jax.tree_util.tree_leaves_with_path(
                specs, is_leaf=lambda s: isinstance(s, sh.P))}
    wq = [v for k, v in flat.items() if k.endswith("attn/wq/w")][0]
    assert wq == sh.P(None, "data", "model")
    emb = flat["embed/embedding"]
    assert emb == sh.P("model", "data")
    mlp_wo = [v for k, v in flat.items() if k.endswith("mlp/wo/w")][0]
    assert mlp_wo == sh.P(None, "model", "data")


def test_multipod_batch_rule():
    r1 = sh.make_rules("train", multi_pod=False)
    r2 = sh.make_rules("train", multi_pod=True)
    assert r1["batch"] == ("data",)
    assert r2["batch"] == ("pod", "data")
    rl = sh.make_rules("decode", multi_pod=False, long_context=True)
    assert rl["batch"] is None and rl["kv_len"] == ("data",)


def test_moe_expert_sharding():
    cfg = smoke_variant(get_config("deepseek-moe-16b"))
    shapes = jax.eval_shape(lambda: lm.init_lm(cfg, jax.random.PRNGKey(0)))
    rules = sh.make_rules("train", multi_pod=False)
    specs = sh.param_specs(shapes, rules)
    flat = {"/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path): s
            for path, s in jax.tree_util.tree_leaves_with_path(
                specs, is_leaf=lambda s: isinstance(s, sh.P))}
    wi = [v for k, v in flat.items() if k.endswith("moe/experts/wi")][0]
    assert wi == sh.P(None, "model", "data", None)    # EP x FSDP


def test_real_spmd_train_step_8dev():
    """End-to-end: 8 forced host devices, (4 data x 2 model) mesh, real
    sharded train step executes and loss is finite."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config, smoke_variant
from repro.dist import sharding as sh
from repro.launch import steps as St
from repro.models import lm
from repro.optim import adamw_init

cfg = dataclasses.replace(smoke_variant(get_config("qwen2-1.5b")),
                          d_model=64, num_heads=4, num_kv_heads=2,
                          grad_accum=2)
mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = sh.make_rules("train", multi_pod=False)
state_shapes = St.state_specs(cfg)
pspecs = sh.param_specs(state_shapes["params"], rules)
sspecs = {"params": pspecs, "opt": sh.opt_specs(pspecs), "step": sh.P()}
from jax.sharding import NamedSharding
act = NamedSharding(mesh, sh.P(rules["batch"], None, None))
step = jax.jit(St.make_train_step(cfg, act_spec=act, moe_groups=4,
                                  peak_lr=1e-2),
               in_shardings=(sh.named(mesh, sspecs), None),
               out_shardings=(sh.named(mesh, sspecs), None),
               donate_argnums=(0,))
params = lm.init_lm(cfg, jax.random.PRNGKey(0))
state = {"params": params, "opt": adamw_init(params),
         "step": jnp.zeros((), jnp.int32)}
state = jax.device_put(state, sh.named(mesh, sspecs))
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, 256, (8, 16)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, 256, (8, 16)), jnp.int32)}
l0 = None
for i in range(3):
    state, m = step(state, batch)
    assert np.isfinite(m["loss"])
    l0 = l0 or float(m["loss"])
assert float(m["loss"]) < l0    # memorizing one batch
print("SPMD_OK", float(m["loss"]))
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], cwd=Path.cwd(),
                         env=env, capture_output=True, text=True,
                         timeout=560)
    assert "SPMD_OK" in out.stdout, out.stderr[-3000:]


def test_serve_helpers_replicate_once_and_batch_shard():
    """The data-parallel serving helpers the sharded dispatcher is
    built on: replicate_params moves a host tree exactly once (already
    replicated leaves pass through by identity), batch_sharded cuts
    only the leading axis."""
    import numpy as np

    from repro.launch.mesh import SERVE_AXIS, make_serve_mesh

    mesh = make_serve_mesh()
    params = {"w": np.ones((4, 3), np.float32),
              "inner": {"b": np.zeros((3,), np.float32)}}
    rep = sh.replicate_params(params, mesh)
    leaves = jax.tree.leaves(rep)
    assert all(sh.is_replicated_on(leaf, mesh) for leaf in leaves)
    assert not sh.is_replicated_on(params["w"], mesh)   # host array isn't
    # second replication is the identity — no re-transfer
    rep2 = sh.replicate_params(rep, mesh)
    assert all(a is b for a, b in zip(leaves, jax.tree.leaves(rep2)))

    assert sh.replicated(mesh).spec == sh.P()
    assert sh.batch_sharded(mesh, 4).spec == sh.P(
        SERVE_AXIS, None, None, None)
    assert sh.batch_sharded(mesh, 1).spec == sh.P(SERVE_AXIS)
    with pytest.raises(ValueError, match="rank"):
        sh.batch_sharded(mesh, 0)
    with pytest.raises(ValueError, match="n_devices"):
        make_serve_mesh(len(jax.local_devices()) + 1)


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %all-gather.46 = f32[16,4096,1,128]{2,1,0,3} all-gather(%x), dims={3}
  %fusion.1 = f32[4,4]{1,0} fusion(%all-reduce.189), calls=%c
  %all-reduce.189 = f32[256,4096]{1,0} all-reduce(%w), channel_id=1
  %all-to-all.40 = (f32[1,32,8]{2,1,0}, f32[1,32,8]{2,1,0}) all-to-all(%a, %b)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"]["bytes"] == 16 * 4096 * 128 * 4
    assert out["all-reduce"]["bytes"] == 256 * 4096 * 4
    assert out["all-to-all"]["bytes"] == 2 * 32 * 8 * 4
    assert out["all-gather"]["count"] == 1
