"""ConvSpec plan layer: dispatch, epilogues, strides, fallbacks, cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cuconv as cc
from repro.core import convspec as cs
from repro.core import executors as ex

TOLS = {"float32": dict(rtol=3e-4, atol=3e-4),
        "bfloat16": dict(rtol=3e-2, atol=3e-2)}


@pytest.fixture(autouse=True)
def _hermetic_autotune_cache(tmp_path, monkeypatch):
    """plan() consults the persisted measured cache; point it at an
    empty per-test dir so earlier sweeps on this machine can't leak
    into heuristic assertions."""
    from repro.core import autotune
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "autotune"))
    autotune.clear_cache()
    yield
    autotune.clear_cache()


def _mk(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape), jnp.float32).astype(dtype)


def _lax_ref(x, w, stride, padding, bias=None, relu=False):
    y = cc.conv_lax(x.astype(jnp.float32), w.astype(jnp.float32),
                    stride, padding)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if relu:
        y = jax.nn.relu(y)
    return y


# ---------------------------------------------------------------------------
# dispatch equivalence sweep: every algorithm x stride x padding x dtype x K

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("K", [1, 3, 5])
@pytest.mark.parametrize("padding", ["same", "valid", 1])
@pytest.mark.parametrize("stride", [1, 2])
def test_all_algorithms_match_lax(rng, stride, padding, K, dtype):
    x = _mk(rng, (2, 10, 10, 6), dtype)
    w = _mk(rng, (K, K, 6, 5), dtype)
    want = _lax_ref(x, w, stride, padding)
    tols = TOLS[str(jnp.dtype(dtype))]
    spec = cs.ConvSpec.for_conv(x, w, stride, padding)
    for name in ["im2col", "cuconv_two_stage", "cuconv_two_stage_pallas",
                 "conv1x1_pallas", "cuconv", "cuconv_pallas", "winograd",
                 "lax"]:
        if not cs.supports(name, spec)[0]:
            continue       # forcing would fall back: lax==lax proves nothing
        got = cc.conv2d(x, w, stride, padding, algorithm=name)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want),
            err_msg=f"{name} stride={stride} pad={padding} K={K}", **tols)


@pytest.mark.parametrize("epilogue", ["bias", "relu", "bias_relu"])
@pytest.mark.parametrize("K", [1, 3])
def test_every_algorithm_matches_lax_for_every_epilogue(rng, K, epilogue):
    """Every algorithm x epilogue lands on relu?(conv + bias?) exactly
    (fused in-kernel on the Pallas path, XLA ops elsewhere)."""
    x = _mk(rng, (1, 8, 8, 6), jnp.float32)
    w = _mk(rng, (K, K, 6, 4), jnp.float32)
    bias = _mk(rng, (4,), jnp.float32) if "bias" in epilogue else None
    act = "relu" if "relu" in epilogue else None
    want = _lax_ref(x, w, 1, "same", bias=bias, relu=act == "relu")
    spec = cs.ConvSpec.for_conv(x, w, 1, "same", bias=bias, activation=act)
    assert spec.epilogue == epilogue
    for name in ex.names():
        if not cs.supports(name, spec)[0]:
            continue
        got = cc.conv2d(x, w, 1, "same", algorithm=name, bias=bias,
                        activation=act)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want),
            err_msg=f"{name} K={K} epilogue={epilogue}", **TOLS["float32"])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("stride", [1, 2])
def test_fused_epilogue_matches_lax(rng, stride, dtype):
    """The planned bias+ReLU epilogue (fused on the Pallas path, XLA ops
    elsewhere) equals relu(conv_lax + b) for every algorithm."""
    x = _mk(rng, (1, 9, 9, 8), dtype)
    w = _mk(rng, (3, 3, 8, 4), dtype)
    b = _mk(rng, (4,), dtype)
    want = _lax_ref(x, w, stride, "same", bias=b, relu=True)
    tols = TOLS[str(jnp.dtype(dtype))]
    for name in ["auto", "cuconv", "cuconv_pallas", "lax"]:
        got = cc.conv2d(x, w, stride, "same", algorithm=name,
                        bias=b, activation="relu")
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want),
            err_msg=f"{name} stride={stride}", **tols)


# ---------------------------------------------------------------------------
# plan() policy

def test_auto_routes_through_plan():
    spec = cs.ConvSpec((1, 7, 7, 32), (1, 1, 32, 16))
    p = cs.plan(spec)
    assert p.source in ("heuristic", "measured")
    assert p.algorithm in ex.names()
    assert p.algorithm in p.explain() and spec.key() in p.explain()
    assert "dtype=float32" in p.explain()             # precision provenance


def test_plan_respects_vmem_budget_fallback():
    """Oversized fused working sets take the two-stage path (the guard
    that used to live in kernels/ops.py — now the fused executor's own
    capability declaration)."""
    spec = cs.ConvSpec((1, 8, 2100, 1024), (3, 3, 1024, 8),
                       stride=(1, 1), padding=(1, 1))
    assert ex.get("cuconv_pallas").vmem_bytes(spec) > ex.FUSED_VMEM_BUDGET
    p = cs.plan(spec, force="cuconv_pallas")
    assert p.algorithm == "cuconv_two_stage_pallas"
    assert p.source == "fallback"
    assert "VMEM" in p.explain()
    # strided oversized specs cannot take the stride-1 two-stage kernels
    sspec = cs.ConvSpec((1, 8, 4100, 1024), (3, 3, 1024, 8),
                        stride=(2, 2), padding=(1, 1))
    sp = cs.plan(sspec, force="cuconv_pallas")
    assert sp.algorithm == "cuconv"


def test_plan_fallback_is_numerically_correct(rng):
    """A fallback plan still computes the right answer."""
    x = _mk(rng, (1, 6, 300, 64), jnp.float32)
    w = _mk(rng, (3, 3, 64, 4), jnp.float32)
    spec = cs.ConvSpec.for_conv(x, w, 1, "same")
    old = ex.FUSED_VMEM_BUDGET
    try:
        ex.FUSED_VMEM_BUDGET = 1024            # force the guard to trip
        p = cs.plan(spec, force="cuconv_pallas")
        assert p.source == "fallback"
        got = p(x, w)
    finally:
        ex.FUSED_VMEM_BUDGET = old
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(_lax_ref(x, w, 1, "same")),
                               rtol=3e-4, atol=3e-4)


def test_forced_unknown_algorithm_raises():
    spec = cs.ConvSpec((1, 4, 4, 2), (1, 1, 2, 2))
    with pytest.raises(KeyError):
        cs.plan(spec, force="conv9000")


def test_forced_unsupported_lands_on_documented_fallbacks():
    """Every forced-but-unsupported algorithm takes _fallback_for's
    documented stand-in."""
    spec3 = cs.ConvSpec((1, 8, 8, 4), (3, 3, 4, 4), (1, 1), (1, 1))
    p = cs.plan(spec3, force="conv1x1_pallas")        # needs 1x1
    assert (p.source, p.algorithm) == ("fallback", "lax")
    strided = cs.ConvSpec((1, 8, 8, 4), (3, 3, 4, 4), (2, 2), (1, 1))
    p = cs.plan(strided, force="cuconv_two_stage_pallas")  # stride-1 only
    assert (p.source, p.algorithm) == ("fallback", "lax")
    p = cs.plan(strided, force="winograd")            # 3x3 stride-1 only
    assert (p.source, p.algorithm) == ("fallback", "lax")


def test_normalize_pad_and_stride_validation():
    assert cs.normalize_pad("same", 3, 3) == (1, 1)
    assert cs.normalize_pad((2, 1), 3, 3) == (2, 1)
    with pytest.raises(ValueError):
        cs.normalize_pad(-1, 3, 3)
    with pytest.raises(ValueError):
        cs.normalize_pad((1, 2, 3), 3, 3)             # 3-tuple: was silent
    with pytest.raises(ValueError):
        cs.normalize_pad((-1, 0), 3, 3)
    with pytest.raises(ValueError):
        cs.normalize_stride(0)
    with pytest.raises(ValueError):
        cs.normalize_stride((1, 2, 3))


def test_spec_rejects_nonpositive_output():
    with pytest.raises(ValueError):
        cs.ConvSpec((1, 2, 2, 1), (5, 5, 1, 1))       # filter > padded input


def test_spec_direct_construction_validates_stride_and_pad():
    """Direct ConvSpec construction is as strict as the normalize_* path."""
    with pytest.raises(ValueError):
        cs.ConvSpec((1, 8, 8, 4), (3, 3, 4, 4), (0, 1), (1, 1))
    with pytest.raises(ValueError):
        cs.ConvSpec((1, 8, 8, 4), (3, 3, 4, 4), (1, 1), (-1, -1))


def test_spec_key_stable_and_epilogue_sensitive():
    a = cs.ConvSpec((1, 7, 7, 8), (3, 3, 8, 4), (2, 2), (1, 1),
                    "float32", "bias_relu")
    assert a.key() == "n1h7w7c8-k3x3m4-s2x2-p1x1-float32-bias_relu"
    b = cs.ConvSpec((1, 7, 7, 8), (3, 3, 8, 4), (2, 2), (1, 1))
    assert a.key() != b.key()
    assert a.out_shape == (1, 4, 4, 4)


def test_heuristic_regions_via_plan():
    """The paper's regions, now owned by plan() (CPU backend)."""
    mk = lambda xs, ws, s: cs.plan(cs.ConvSpec(xs, ws, (s, s))).algorithm
    assert mk((1, 7, 7, 832), (1, 1, 832, 256), 1) == "cuconv"
    assert mk((64, 56, 56, 128), (3, 3, 128, 128), 1) == "winograd"
    assert mk((1, 7, 7, 64), (3, 3, 64, 64), 2) == "lax"


def test_tpu_backend_prefers_fused_kernel():
    spec = cs.ConvSpec((1, 7, 7, 192), (3, 3, 192, 384), (2, 2), (1, 1))
    p = cs.plan(spec, backend="tpu")
    assert p.algorithm == "cuconv_pallas"
    # bare 1x1 takes the dedicated GEMM kernel; with an epilogue the
    # fused kernel wins (epilogue applied in VMEM, no extra round trip)
    one = cs.ConvSpec((1, 7, 7, 832), (1, 1, 832, 256))
    assert cs.plan(one, backend="tpu").algorithm == "conv1x1_pallas"
    one_epi = cs.ConvSpec((1, 7, 7, 832), (1, 1, 832, 256),
                          epilogue="bias_relu")
    assert cs.plan(one_epi, backend="tpu").algorithm == "cuconv_pallas"


# ---------------------------------------------------------------------------
# persisted measured cache

def test_measured_cache_persists_across_reload(rng, tmp_path, monkeypatch):
    from repro.core import autotune
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    autotune.clear_cache()
    x = _mk(rng, (1, 6, 6, 8), jnp.float32)
    w = _mk(rng, (1, 1, 8, 4), jnp.float32)
    best = autotune.measure_algorithm(x, w, repeats=1,
                                      candidates=("lax", "cuconv"))
    assert best in ("lax", "cuconv")
    assert (tmp_path / "autotune.json").exists()
    # a fresh process (simulated by dropping the in-memory mirror) reads
    # the measured winner back and plan() serves it
    autotune.clear_cache()
    spec = cs.ConvSpec.for_conv(x, w, 1, "same")
    assert autotune.cached_best(spec) == best
    p = cs.plan(spec)
    assert p.source == "measured" and p.algorithm == best


def test_measured_winner_serves_epilogue_specs(rng, tmp_path, monkeypatch):
    """A sweep measured without an epilogue must pay off for the real
    model path, whose specs carry bias_relu (cache key is
    epilogue-insensitive)."""
    from repro.core import autotune
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    autotune.clear_cache()
    x = _mk(rng, (1, 6, 6, 8), jnp.float32)
    w = _mk(rng, (3, 3, 8, 4), jnp.float32)
    best = autotune.measure_algorithm(x, w, repeats=1,
                                      candidates=("lax", "cuconv"))
    spec = cs.ConvSpec.for_conv(x, w, 1, "same", bias=jnp.zeros((4,)),
                                activation="relu")
    p = cs.plan(spec)
    assert p.source == "measured" and p.algorithm == best


def test_measured_cache_ignored_for_other_spec(rng, tmp_path, monkeypatch):
    from repro.core import autotune
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    autotune.clear_cache()
    spec = cs.ConvSpec((1, 5, 5, 4), (3, 3, 4, 4))
    assert autotune.cached_best(spec) is None
    assert cs.plan(spec).source == "heuristic"


def test_measure_default_candidates_include_pallas(rng, tmp_path, monkeypatch):
    """Measured mode must be able to pick the kernels this repo exists
    to showcase: the default candidate set is every registered executor
    filtered by its declared capabilities, and bias/activation ride into
    the timed executions."""
    from repro.core import autotune
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    autotune.clear_cache()
    spec = cs.ConvSpec((1, 4, 4, 4), (1, 1, 4, 3))
    cands = set(autotune.default_candidates(spec))
    assert {"cuconv_pallas", "conv1x1_pallas",
            "cuconv_two_stage_pallas"} <= cands
    strided = cs.ConvSpec((1, 8, 8, 4), (3, 3, 4, 3), (2, 2), (1, 1))
    assert "cuconv_two_stage_pallas" not in set(
        autotune.default_candidates(strided))
    # the full default sweep runs (Pallas in interpret mode here) and
    # times the fused-epilogue deployment, not the bare conv
    x = _mk(rng, (1, 4, 4, 4), jnp.float32)
    w = _mk(rng, (1, 1, 4, 3), jnp.float32)
    b = _mk(rng, (3,), jnp.float32)
    best = autotune.measure_algorithm(x, w, repeats=1, bias=b,
                                      activation="relu")
    assert best in ex.names()
