"""Per-kernel allclose sweeps vs the ref.py oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import conv1x1 as k1, cuconv_stage1 as ks1, \
    cuconv_stage2 as ks2, cuconv_fused as kf, conv1d_tap as kc, \
    flash_attention as kfa

TOLS = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
        jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape), jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("P,C,M", [(64, 32, 16), (300, 130, 70),
                                   (17, 257, 129), (1024, 64, 256)])
def test_conv1x1_gemm(rng, P, C, M, dtype):
    x = _rand(rng, (P, C), dtype)
    w = _rand(rng, (C, M), dtype)
    got = k1.conv1x1_gemm(x, w, interpret=True)
    want = ref.conv1x1_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOLS[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,P,C,M", [(9, 50, 16, 8), (25, 128, 48, 32),
                                     (4, 33, 7, 5)])
def test_stage1(rng, T, P, C, M, dtype):
    xs = _rand(rng, (T, P, C), dtype)
    w = _rand(rng, (T, C, M), dtype)
    got = ks1.stage1_tap_gemm(xs, w, interpret=True)
    want = ref.stage1_ref(xs, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **TOLS[dtype])


@pytest.mark.parametrize("T,P,M", [(9, 64, 32), (25, 100, 20), (1, 7, 3)])
def test_stage2(rng, T, P, M):
    temps = _rand(rng, (T, P, M), jnp.float32)
    got = ks2.stage2_tap_sum(temps, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.stage2_ref(
        temps)), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("N,H,W,C,KH,KW,M,pad", [
    (1, 7, 7, 16, 3, 3, 8, 1),
    (2, 9, 11, 4, 5, 5, 6, 2),
    (1, 13, 13, 32, 3, 3, 16, 1),
    (2, 8, 8, 8, 1, 1, 12, 0),
    (1, 6, 6, 3, 3, 3, 5, 0),
])
def test_cuconv_fused_kernel(rng, N, H, W, C, KH, KW, M, pad, dtype):
    x = _rand(rng, (N, H, W, C), dtype)
    w = _rand(rng, (KH, KW, C, M), dtype)
    got = ops.cuconv_fused(x, w, (pad, pad), interpret=True)
    want = ref.conv2d_pad_ref(x.astype(jnp.float32), w.astype(jnp.float32),
                              (pad, pad))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), **TOLS[dtype])


@pytest.mark.parametrize("N,H,W,C,KH,KW,M,pad", [
    (1, 7, 7, 16, 3, 3, 8, 1),
    (2, 9, 9, 8, 5, 5, 4, 2),
])
def test_cuconv_two_stage_kernels(rng, N, H, W, C, KH, KW, M, pad):
    x = _rand(rng, (N, H, W, C), jnp.float32)
    w = _rand(rng, (KH, KW, C, M), jnp.float32)
    got = ops.cuconv_two_stage(x, w, (pad, pad), interpret=True)
    want = ref.conv2d_pad_ref(x, w, (pad, pad))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,L,D,K", [(2, 37, 24, 4), (1, 128, 64, 4),
                                     (3, 16, 8, 2)])
def test_conv1d_tap(rng, B, L, D, K, dtype):
    x = _rand(rng, (B, L, D), dtype)
    w = _rand(rng, (K, D), dtype)
    b = _rand(rng, (D,), dtype)
    got = ops.conv1d_causal(x, w, b, interpret=True)
    want = ref.conv1d_ref(x, w, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOLS[dtype])


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("BH,Sq,Sk,D", [(3, 40, 40, 16), (2, 100, 100, 32),
                                        (1, 64, 128, 8)])
def test_flash_attention(rng, BH, Sq, Sk, D, causal):
    if causal and Sq != Sk:
        pytest.skip("causal requires square here")
    q = _rand(rng, (BH, Sq, D), jnp.float32)
    k = _rand(rng, (BH, Sk, D), jnp.float32)
    v = _rand(rng, (BH, Sk, D), jnp.float32)
    got = kfa.flash_attention(q, k, v, causal=causal, tq=32, tk=32,
                              interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_gqa_wrapper(rng):
    B, S, H, KVH, D = 2, 32, 8, 2, 16
    q = _rand(rng, (B, S, H, D), jnp.float32)
    k = _rand(rng, (B, S, KVH, D), jnp.float32)
    v = _rand(rng, (B, S, KVH, D), jnp.float32)
    got = ops.flash_attention(q, k, v, interpret=True)
    from repro.nn.attention import exact_attention, _repeat_kv
    want = exact_attention(q, _repeat_kv(k, H), _repeat_kv(v, H))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("stride", [(2, 2), (2, 1), (3, 2)])
@pytest.mark.parametrize("N,H,W,C,KH,KW,M,pad", [
    (1, 9, 9, 8, 3, 3, 6, 1),
    (2, 11, 13, 4, 5, 5, 3, 2),
])
def test_cuconv_fused_strided(rng, N, H, W, C, KH, KW, M, pad, stride):
    """The generalized kernel matches the library conv at any stride."""
    x = _rand(rng, (N, H, W, C), jnp.float32)
    w = _rand(rng, (KH, KW, C, M), jnp.float32)
    got = ops.cuconv_fused(x, w, (pad, pad), stride=stride, interpret=True)
    want = jax.lax.conv_general_dilated(
        x, w, stride, ((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("KH,KW", [(1, 1), (3, 3)])
def test_cuconv_fused_epilogue(rng, KH, KW, stride):
    """bias+ReLU accumulated in VMEM on the final tap == relu(conv + b)."""
    x = _rand(rng, (2, 8, 8, 8), jnp.float32)
    w = _rand(rng, (KH, KW, 8, 12), jnp.float32)
    b = _rand(rng, (12,), jnp.float32)
    pad = (KH - 1) // 2
    got = ops.cuconv_fused(x, w, (pad, pad), stride=stride, bias=b,
                           activation="relu", interpret=True)
    want = jax.nn.relu(jax.lax.conv_general_dilated(
        x, w, (stride, stride), ((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
