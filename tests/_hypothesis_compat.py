"""Minimal deterministic stand-in for the hypothesis API subset we use.

CI images without hypothesis (no network installs) fall back to this:
`given` draws `max_examples` pseudo-random examples from a fixed seed, so
runs are reproducible; `assume` skips an example without counting it.
Only the strategies this suite uses are provided (integers, sampled_from,
tuples).
"""
from __future__ import annotations


import random
import types


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(lo, hi):
    return _Strategy(lambda r: r.randint(lo, hi))


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda r: r.choice(seq))


def tuples(*ss):
    return _Strategy(lambda r: tuple(s.draw(r) for s in ss))


strategies = types.SimpleNamespace(
    integers=integers, sampled_from=sampled_from, tuples=tuples)


class _Unsatisfied(Exception):
    pass


def assume(cond):
    if not cond:
        raise _Unsatisfied()


def settings(max_examples=100, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*ss):
    def deco(fn):
        # no functools.wraps: pytest must see a zero-arg function, not
        # the wrapped signature (it would demand fixtures for each param)
        def wrapper():
            n = getattr(wrapper, "_max_examples",
                        getattr(fn, "_max_examples", 30))
            r = random.Random(0)
            ran = 0
            for _ in range(n * 20):
                if ran >= n:
                    break
                vals = tuple(s.draw(r) for s in ss)
                try:
                    fn(*vals)
                except _Unsatisfied:
                    continue
                ran += 1
            if ran == 0:                 # mirror hypothesis.Unsatisfiable
                raise RuntimeError(
                    f"{fn.__name__}: no examples satisfied assume()")
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
