"""Launch-config tuning layer (DESIGN.md §9): candidate spaces, the
(algorithm, config) plan pair, the versioned autotune cache.

Covers the acceptance surface of the tuning PR:

  * numerics — every candidate launch config of every executor, over a
    grid of specs, matches the fp32 library reference (interpret mode);
  * feasibility — each Pallas executor exposes >= 3 VMEM-feasible
    candidates on the paper's profiled table-3/4 shapes;
  * forcing — an infeasible forced config raises a clear error naming
    executor, config and spec;
  * staleness — a persisted config invalid under the current geometry
    (e.g. ``rows`` > OH) or an unversioned/foreign-schema cache entry
    is dropped and re-resolved, never served;
  * round-trip — ``plan(tune="full")`` measures >= 3 feasible
    candidates, persists the winner under the versioned schema, and a
    later plan replays it with ZERO re-measurement (MEASURE_STATS).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune
from repro.core import convspec as cs
from repro.core import cuconv as cc
from repro.core import executors as ex
from repro.core.plancache import cache_dir

TOLS = {"float32": dict(rtol=3e-4, atol=3e-4),
        "bfloat16": dict(rtol=3e-2, atol=3e-2)}


@pytest.fixture(autouse=True)
def _hermetic_autotune_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "cache"))
    autotune.clear_cache()
    autotune.reset_measure_stats()
    yield
    autotune.clear_cache()


# spec grid: kernel size / stride / padding / epilogue / dtype coverage
# for the per-candidate numerics sweep (small shapes: interpret mode)
GEOMS = [
    ((1, 8, 8, 6), (3, 3), 4, (1, 1), (1, 1), "bias_relu"),
    ((2, 9, 9, 5), (3, 3), 4, (2, 2), (1, 1), "none"),
    ((1, 6, 6, 8), (1, 1), 4, (1, 1), (0, 0), "bias"),
    ((1, 7, 7, 4), (5, 5), 3, (1, 1), (2, 2), "none"),
    ((1, 12, 5, 6), (3, 3), 5, (2, 1), (1, 1), "relu"),
]

# the paper's profiled configurations each Pallas executor must expose
# a real tuning space on (table 3 A for the 1x1 kernel, table 4 A/B for
# the KxK kernels)
T3_A = cs.ConvSpec((1, 7, 7, 832), (1, 1, 832, 256))
T4_A = cs.ConvSpec((1, 7, 7, 192), (3, 3, 192, 384), (1, 1), (1, 1))
T4_B = cs.ConvSpec((1, 13, 13, 384), (3, 3, 384, 384), (1, 1), (1, 1))

PALLAS = ("cuconv_pallas", "cuconv_two_stage_pallas", "conv1x1_pallas",
          "winograd_pallas", "direct")


def _spec(geom, dtype="float32"):
    in_shape, (kh, kw), m, stride, padding, epi = geom
    return cs.ConvSpec(in_shape, (kh, kw, in_shape[3], m), stride, padding,
                       dtype, epi)


def _operands(spec, rng):
    dtype = jnp.dtype(spec.dtype)
    x = jnp.asarray(rng.normal(size=spec.in_shape), jnp.float32) \
        .astype(dtype)
    w = jnp.asarray(rng.normal(size=spec.filter_shape), jnp.float32) \
        .astype(dtype)
    b = (jnp.asarray(rng.normal(size=(spec.filter_shape[3],)), jnp.float32)
         .astype(dtype) if spec.has_bias else None)
    return x, w, b


def _f32_ref(spec, x, w, b):
    y = cc.conv_lax(x.astype(jnp.float32), w.astype(jnp.float32),
                    spec.stride, spec.padding, groups=spec.groups)
    if spec.has_bias:
        y = y + b.astype(jnp.float32)
    if spec.wants_relu:
        y = jax.nn.relu(y)
    return np.asarray(y)


# ---------------------------------------------------------------------------
# candidate space declarations

def test_candidate_zero_is_the_historical_geometry():
    """Candidate 0 of every tunable executor is the hard-coded pre-tuning
    geometry (clamped to the spec), so nothing regresses by default."""
    fused = ex.get("cuconv_pallas").configs(T4_A)[0]
    assert fused.as_dict() == {"tm": 128, "rows": 1}
    ts = ex.get("cuconv_two_stage_pallas").configs(T4_A)[0]
    assert ts.as_dict() == {"tp": 49, "tm": 128, "tc": 192}   # tp clamped
    one = ex.get("conv1x1_pallas").configs(T3_A)[0]
    assert one.as_dict() == {"tp": 49, "tm": 128, "tc": 512}
    # winograd_pallas candidate 0 is the F(2,3) variant at the default
    # tiles (tt clamped to the spec's tile count: 1 * ceil(7/2)^2 = 16)
    wg = ex.get("winograd_pallas").configs(T4_A)[0]
    assert wg.as_dict() == {"m": 2, "tt": 16, "tm": 128, "tc": 128}
    # direct candidate 0: default (tm, tc) clamped to (M, C)
    dc = ex.get("direct").configs(T4_A)[0]
    assert dc.as_dict() == {"tm": 128, "tc": 192}


@pytest.mark.parametrize("name,spec", [
    ("cuconv_pallas", T4_A), ("cuconv_pallas", T4_B),
    ("cuconv_two_stage_pallas", T4_A), ("cuconv_two_stage_pallas", T4_B),
    ("conv1x1_pallas", T3_A),
    ("winograd_pallas", T4_A), ("winograd_pallas", T4_B),
    ("direct", T4_B), ("direct", T3_A),
])
def test_pallas_executors_expose_three_feasible_candidates(name, spec):
    """Acceptance: >= 3 VMEM-feasible candidate configs per Pallas
    executor on the paper's profiled shapes (pruned through
    config_supports BEFORE any measurement)."""
    exe = ex.get(name)
    feasible = [c for c in exe.configs(spec)
                if exe.config_supports(spec, c)[0]]
    assert len(feasible) >= 3, (name, [c.key() for c in feasible])
    # candidates are deduplicated after clamping
    assert len(set(feasible)) == len(feasible)


def test_untunable_executors_have_one_empty_config():
    for name in ("lax", "im2col", "winograd", "cuconv", "cuconv_two_stage"):
        exe = ex.get(name)
        assert exe.tunable == ()
        (only,) = exe.configs(T4_A)
        assert not only and only.as_dict() == {}
        assert exe.default_config(T4_A) == only


def test_default_config_is_vmem_feasible_and_model_ranked():
    """default_config picks a feasible candidate by the executor's
    config-cost model — never one the VMEM budget rejects."""
    for name in PALLAS:
        exe = ex.get(name)
        for spec in (T4_A, T3_A):
            if not exe.supports(spec)[0]:
                continue
            cfg = exe.default_config(spec)
            ok, why = exe.config_supports(spec, cfg)
            assert ok, (name, cfg.key(), why)
            # the model never ranks a feasible candidate above a cheaper one
            feas = [c for c in exe.configs(spec)
                    if exe.config_supports(spec, c)[0]]
            best = min(exe.config_cost(spec, c) for c in feas)
            assert exe.config_cost(spec, cfg) == best


# ---------------------------------------------------------------------------
# numerics: every candidate config executes exactly

@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("name", PALLAS)
def test_every_candidate_config_matches_lax(rng, name, dtype):
    exe = ex.get(name)
    ran = 0
    for geom in GEOMS:
        spec = _spec(geom, dtype)
        if not exe.supports(spec)[0]:
            continue
        x, w, b = _operands(spec, rng)
        want = _f32_ref(spec, x, w, b)
        for cfg in exe.configs(spec):
            if not exe.config_supports(spec, cfg)[0]:
                continue
            ran += 1
            got = exe.execute(spec, x, w, bias=b, config=cfg)
            np.testing.assert_allclose(
                np.asarray(got, np.float32), want,
                err_msg=f"{name} cfg[{cfg.key()}] {spec.key()}",
                **TOLS[dtype])
    assert ran > 0, f"{name} ran no candidate configs over the grid"


# ---------------------------------------------------------------------------
# forcing

def test_forced_infeasible_config_raises_naming_executor_config_spec():
    spec = _spec(GEOMS[0])                              # OH = 8
    with pytest.raises(ValueError) as e:
        cs.plan(spec, force="cuconv_pallas", config={"tm": 128, "rows": 64})
    msg = str(e.value)
    assert "cuconv_pallas" in msg and "rows" in msg and spec.key() in msg
    # a config whose working set blows the VMEM budget is refused too
    big = cs.ConvSpec((1, 8, 1200, 1024), (3, 3, 1024, 256),
                      (1, 1), (1, 1))
    assert ex.get("cuconv_pallas").supports(big)[0]     # default cfg fits
    with pytest.raises(ValueError, match="VMEM"):
        cs.plan(big, force="cuconv_pallas", config={"tm": 256, "rows": 8})
    # unknown dims are named, not silently ignored
    with pytest.raises(ValueError, match="tunable"):
        cs.plan(spec, force="cuconv_pallas", config={"warp": 4})
    # untunable executors refuse any non-empty config
    with pytest.raises(ValueError, match="lax"):
        cs.plan(spec, force="lax", config={"tm": 128})


def test_forced_infeasible_config_raises_for_new_executors():
    """The PR-10 executors honor the same loud-raise contract: a forced
    config outside the tuning space names executor, config and spec."""
    # F(m,3) variant is a config dim but only m in {2, 4} exists
    with pytest.raises(ValueError) as e:
        cs.plan(T4_A, force="winograd_pallas",
                config={"m": 3, "tt": 16, "tm": 128, "tc": 128})
    msg = str(e.value)
    assert "winograd_pallas" in msg and "m=3" in msg and T4_A.key() in msg
    # oversized tiles blow the (unclamped) VMEM model and are refused
    with pytest.raises(ValueError, match="VMEM"):
        cs.plan(T4_B, force="winograd_pallas",
                config={"m": 4, "tt": 512, "tm": 512, "tc": 512})
    with pytest.raises(ValueError) as e:
        cs.plan(T4_B, force="direct", config={"tm": 512, "tc": 512})
    msg = str(e.value)
    assert "direct" in msg and "VMEM" in msg and T4_B.key() in msg


def test_forced_valid_config_rides_the_plan(rng):
    spec = _spec(GEOMS[0])
    p = cs.plan(spec, force="cuconv_pallas", config={"tm": 4, "rows": 2})
    assert p.config_source == "forced"
    assert p.config.as_dict() == {"tm": 4, "rows": 2}
    assert "cfg[forced]=rows=2,tm=4" in p.explain()
    x, w, b = _operands(spec, rng)
    np.testing.assert_allclose(np.asarray(p(x, w, b), np.float32),
                               _f32_ref(spec, x, w, b), **TOLS["float32"])


# ---------------------------------------------------------------------------
# staleness + schema versioning

def test_stale_persisted_config_is_reresolved_not_served():
    """A persisted config that a geometry change invalidated (rows > OH)
    is dropped at resolve time; the plan gets a valid config instead."""
    spec = _spec(GEOMS[0])                              # OH = 8
    autotune.record_best(spec, "cpu", "cuconv_pallas",
                         config={"tm": 128, "rows": 64})
    p = cs.plan(spec, backend="cpu")
    assert p.algorithm == "cuconv_pallas"               # winner still serves
    assert p.config_source == "default"                 # ...config does not
    ok, _ = ex.get("cuconv_pallas").config_supports(spec, p.config)
    assert ok
    assert p.config.get("rows", 1) <= spec.out_shape[1]


@pytest.mark.parametrize("name,spec,stale", [
    ("winograd_pallas", T4_A, {"m": 3, "tt": 16, "tm": 128, "tc": 128}),
    ("winograd_pallas", T4_B, {"m": 4, "tt": 512, "tm": 512, "tc": 512}),
    ("direct", T4_B, {"tm": 512, "tc": 512}),
])
def test_stale_persisted_config_self_heals_for_new_executors(name, spec,
                                                             stale):
    """PR-5 contract extends to the PR-10 executors: an invalid persisted
    config (schema drift, VMEM-model tightening) is dropped at resolve
    time and the winner re-serves on its default config."""
    autotune.record_best(spec, "cpu", name, config=stale)
    p = cs.plan(spec, backend="cpu", force=name)
    assert p.algorithm == name
    assert p.config_source == "default"
    ok, why = ex.get(name).config_supports(spec, p.config)
    assert ok, why


def test_config_never_leaks_across_algorithms():
    """A config measured for one executor is not served when another
    executor wins the spec."""
    spec = _spec(GEOMS[0])
    autotune.record_best(spec, "cpu", "cuconv_pallas",
                         config={"tm": 4, "rows": 2})
    assert autotune.cached_config(spec, "cpu", "cuconv_pallas") is not None
    assert autotune.cached_config(spec, "cpu", "lax") is None


def test_unversioned_and_foreign_schema_entries_are_dropped():
    """Satellite: autotune.json is schema-versioned like graphplans.json
    — the pre-config era's bare algorithm strings and foreign schemas
    are never misdecoded into the (algorithm, config) shape."""
    spec = _spec(GEOMS[0])
    key = autotune._key(spec, "cpu")
    autotune._STORE.put(key, "cuconv")                  # v1: bare string
    assert autotune.cached_best(spec, "cpu") is None
    assert autotune.cached_config(spec, "cpu") is None
    autotune._STORE.put(key, {"schema": 99, "algorithm": "cuconv"})
    assert autotune.cached_best(spec, "cpu") is None
    autotune._STORE.put(key, {"algorithm": "cuconv"})   # unversioned dict
    assert autotune.cached_best(spec, "cpu") is None
    # plan() falls back to the heuristic tier, not a misdecoded entry
    assert cs.plan(spec, backend="cpu").source in ("heuristic", "cost")
    # a versioned entry with malformed config dims serves the algorithm
    # but drops the config
    autotune._STORE.put(key, {"schema": autotune.AUTOTUNE_SCHEMA,
                              "algorithm": "cuconv_pallas",
                              "configs": {"cuconv_pallas":
                                          {"tm": "huge"}}})
    assert autotune.cached_best(spec, "cpu") == "cuconv_pallas"
    assert autotune.cached_config(spec, "cpu", "cuconv_pallas") is None


def test_algorithm_change_stops_serving_old_executors_config():
    spec = _spec(GEOMS[0])
    autotune.record_best(spec, "cpu", "cuconv_pallas",
                         config={"tm": 4, "rows": 2})
    autotune.record_best(spec, "cpu", "lax")            # algorithm changed
    assert autotune.cached_best(spec, "cpu") == "lax"
    # the new winner has no config of its own...
    assert autotune.cached_config(spec, "cpu") is None
    # ...but the old executor's measurement survives under ITS key (a
    # later forced plan of that executor still replays it)
    got = autotune.cached_config(spec, "cpu", "cuconv_pallas")
    assert got is not None and got.as_dict() == {"tm": 4, "rows": 2}


def test_forced_tune_never_overwrites_the_measured_winner(rng):
    """Tuning a pinned executor's configs (plan(force=..., tune="full"))
    records under that executor's per-algorithm slot; the genuinely
    measured algorithm winner keeps serving unforced plans."""
    spec = cs.ConvSpec((1, 6, 6, 8), (1, 1, 8, 4))
    cs.plan(spec, tune="algo")                  # real executor sweep
    winner = autotune.cached_best(spec)
    assert winner is not None
    forced = "conv1x1_pallas" if winner != "conv1x1_pallas" else "lax"
    p = cs.plan(spec, force=forced, tune="full")
    assert p.algorithm == forced
    # the unforced plan still serves the measured winner, not the
    # forced executor
    assert autotune.cached_best(spec) == winner
    assert cs.plan(spec).algorithm == winner


# ---------------------------------------------------------------------------
# the measured sweep + replay (the CI tuning smoke runs this class of
# test over the paper configs)

def test_plan_tune_full_measures_persists_and_replays(rng):
    """Acceptance: tune="full" sweeps >= 3 feasible candidates of the
    Pallas executor, persists the (algorithm, config) winner under the
    versioned schema, and replays it from cache with ZERO
    re-measurement."""
    spec = cs.ConvSpec((1, 7, 7, 16), (3, 3, 16, 32), (1, 1), (1, 1))
    exe = ex.get("cuconv_pallas")
    feasible = [c for c in exe.configs(spec)
                if exe.config_supports(spec, c)[0]]
    assert len(feasible) >= 3
    autotune.reset_measure_stats()
    p = cs.plan(spec, force="cuconv_pallas", tune="full")
    assert autotune.MEASURE_STATS["config_sweeps"] == 1
    assert autotune.MEASURE_STATS["timed_calls"] >= len(feasible)
    assert p.config_source == "measured"
    assert p.config in feasible
    # persisted under the versioned schema, keyed per algorithm; a
    # forced tune records NO measured-winner algorithm (none was swept)
    raw = json.loads((cache_dir() / "autotune.json").read_text())
    entry = raw[autotune._key(spec, jax.default_backend())]
    assert entry["schema"] == autotune.AUTOTUNE_SCHEMA
    assert entry["algorithm"] is None
    assert entry["configs"]["cuconv_pallas"] == p.config.as_dict()
    # replay: same pair, zero measurement — in this process and in a
    # "fresh" one (simulated by dropping the in-memory mirror)
    autotune.clear_cache()
    autotune.reset_measure_stats()
    p2 = cs.plan(spec, force="cuconv_pallas")
    assert (p2.algorithm, p2.config) == (p.algorithm, p.config)
    assert p2.config_source == "measured"
    assert autotune.MEASURE_STATS["timed_calls"] == 0
    assert autotune.MEASURE_STATS["config_sweeps"] == 0
    # the tuned plan computes the right answer
    x, w, b = _operands(spec, rng)
    np.testing.assert_allclose(np.asarray(p2(x, w), np.float32),
                               _f32_ref(spec, x, w, None),
                               **TOLS["float32"])


def test_tune_algo_then_full_compose():
    """tune="algo" records only the winner; a later tune="full" adds the
    config without re-running the executor sweep."""
    spec = cs.ConvSpec((1, 6, 6, 8), (1, 1, 8, 4))
    cs.plan(spec, tune="algo")
    best = autotune.cached_best(spec)
    assert best is not None
    assert autotune.cached_config(spec) is None or best is not None
    autotune.reset_measure_stats()
    p = cs.plan(spec, tune="full")
    assert autotune.MEASURE_STATS["algo_sweeps"] == 0   # winner cached
    assert p.algorithm == best


def test_tune_rejects_foreign_backend_and_bad_mode():
    spec = _spec(GEOMS[0])
    other = "tpu" if jax.default_backend() != "tpu" else "cpu"
    with pytest.raises(ValueError, match="backend"):
        cs.plan(spec, tune="algo", backend=other)
    with pytest.raises(ValueError, match="tune"):
        cs.plan(spec, tune="everything")


def test_measure_config_short_circuits_on_valid_persisted_config(rng):
    x = jnp.asarray(rng.normal(size=(1, 7, 7, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 8, 16)), jnp.float32)
    algo, cfg = autotune.measure_config(x, w, repeats=1,
                                        algorithm="cuconv_pallas")
    assert cfg
    autotune.reset_measure_stats()
    algo2, cfg2 = autotune.measure_config(x, w, repeats=1,
                                          algorithm="cuconv_pallas")
    assert (algo2, cfg2) == (algo, cfg)
    assert autotune.MEASURE_STATS["timed_calls"] == 0
    # an EXPLICIT candidate list is a request to measure exactly those
    # configs: it bypasses the cached hit and its winner is among them
    wanted = ({"tm": 8, "rows": 1}, {"tm": 16, "rows": 2})
    autotune.reset_measure_stats()
    _, cfg3 = autotune.measure_config(x, w, repeats=1,
                                      algorithm="cuconv_pallas",
                                      candidates=wanted)
    assert cfg3.as_dict() in [dict(d) for d in wanted]
    assert autotune.MEASURE_STATS["timed_calls"] > 0


class _OldStyleExecutor(ex.Executor):
    """A PR4-era third-party executor: pre-config signatures everywhere
    (5-argument _execute, vmem_bytes(self, spec)) and no tuning space."""
    name = "old_style_plugin"

    def vmem_bytes(self, spec):
        return 1024

    def _execute(self, spec, x, w, bias, interpret):
        return cc.conv_lax(x, w, stride=spec.stride, padding=spec.padding)


def test_pre_config_executor_signatures_still_work(rng):
    """Old-signature plugins participate in plans and sweeps untuned —
    never crash with a TypeError from the config plumbing."""
    ex.register(_OldStyleExecutor())
    try:
        spec = _spec(GEOMS[0])
        p = cs.plan(spec, force="old_style_plugin")
        assert p.algorithm == "old_style_plugin"
        x, w, b = _operands(spec, rng)
        np.testing.assert_allclose(np.asarray(p(x, w, b), np.float32),
                                   _f32_ref(spec, x, w, b),
                                   **TOLS["float32"])
        best = autotune.measure_algorithm(
            x, w, stride=spec.stride, padding=spec.padding, repeats=1,
            candidates=("old_style_plugin", "lax"))
        assert best in ("old_style_plugin", "lax")
    finally:
        ex.unregister("old_style_plugin")


class _BrokenTuningExecutor(ex.Executor):
    """Registered executor whose tuning-space declarations raise."""
    name = "broken_tuning_plugin"

    def configs(self, spec):
        raise RuntimeError("broken tuning space")

    def _execute(self, spec, x, w, bias, interpret):
        return cc.conv_lax(x, w, stride=spec.stride, padding=spec.padding)


def test_measure_algorithm_degrades_on_broken_tuning_declarations(rng):
    """One candidate's broken configs()/default_config() skips that
    candidate instead of crashing the whole sweep."""
    ex.register(_BrokenTuningExecutor())
    try:
        spec = _spec(GEOMS[2])
        x, w, b = _operands(spec, rng)
        best = autotune.measure_algorithm(
            x, w, stride=spec.stride, padding=spec.padding, repeats=1,
            candidates=("broken_tuning_plugin", "lax"))
        assert best == "lax"
    finally:
        ex.unregister("broken_tuning_plugin")


def test_forced_tune_algo_still_runs_the_executor_sweep():
    """plan(force=..., tune="algo") is not a silent no-op: the sweep
    runs and records the UNFORCED winner for later unforced plans."""
    spec = cs.ConvSpec((1, 6, 6, 8), (1, 1, 8, 4))
    autotune.reset_measure_stats()
    p = cs.plan(spec, force="conv1x1_pallas", tune="algo")
    assert p.algorithm == "conv1x1_pallas"      # the pin decides this plan
    assert autotune.MEASURE_STATS["algo_sweeps"] == 1
    assert autotune.cached_best(spec) is not None


# ---------------------------------------------------------------------------
# graph layer carries configs

def test_graph_warmup_tune_full_reports_and_replays_configs():
    from repro.core.graph import plan_graph
    from repro.models.cnn import squeezenet_like
    model = squeezenet_like()
    gp = model.graph_plan((1, 16, 16, 3))
    stats = gp.warmup(tune="full", repeats=1)
    assert all("config" in r and "config_source" in r
               for r in stats["nodes"])
    # tuned configs visible in the whole-network explain table where a
    # tunable executor won
    txt = gp.explain()
    for name, p in gp.conv_plans.items():
        if p.config:
            assert f"cfg[{p.config_source}]={p.config.key()}" in txt
    # a fresh plan of the same graph reconstructs from the graph cache
    # and re-resolves each node's measured config with zero measurement
    autotune.reset_measure_stats()
    gp2 = plan_graph(gp.graph, backend=gp.backend)
    assert gp2.source == "graph_cache"
    for name, p in gp.conv_plans.items():
        assert gp2.conv_plans[name].algorithm == p.algorithm
        assert gp2.conv_plans[name].config == p.config
    assert autotune.MEASURE_STATS["timed_calls"] == 0


def test_explain_shows_tuned_multirow_config():
    """Acceptance: explain() reports the fused kernel's multi-row
    blocking with provenance."""
    spec = cs.ConvSpec((1, 7, 7, 16), (3, 3, 16, 32), (1, 1), (1, 1))
    p = cs.plan(spec, force="cuconv_pallas", config={"tm": 32, "rows": 4})
    txt = p.explain()
    assert "cfg[forced]=rows=4,tm=32" in txt
    pd = cs.plan(spec, force="cuconv_pallas")
    assert "cfg[default]=" in pd.explain()
