"""Cross-layer fusion pass (DESIGN.md §10): IR rewriting, capability
negotiation, cache-key discipline, and numerics of the fused programs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune, executors
from repro.core import graph as g
from repro.core.convspec import ConvSpec, plan
from repro.core.graph import GraphBuilder, fuse_graph, plan_graph
from repro.kernels import ops
from repro.models.cnn import fire_like, resnet_like


def _tiny_residual():
    b = GraphBuilder((1, 8, 8, 3))
    stem = b.conv("stem", "input", 3, 4)
    c1 = b.conv("c1", stem, 3, 4, epilogue="bias")
    b.add("sum", (stem, c1), activation="relu")
    return b.graph()


def _conv_pool():
    b = GraphBuilder((2, 8, 8, 3))
    y = b.conv("c0", "input", 3, 8)
    b.pool("pool", y, kind="max", window=2)
    return b.graph()


def _params_for(graph, rng, scale=0.1):
    params = {}
    for n in graph.nodes:
        if isinstance(n, g.ConvOp):
            s = n.spec
            params[n.name] = {
                "w": jnp.asarray(rng.standard_normal(
                    s.filter_shape, dtype=np.float32) * scale)}
            if s.has_bias:
                params[n.name]["b"] = jnp.asarray(rng.standard_normal(
                    (s.filter_shape[3],), dtype=np.float32) * scale)
        elif isinstance(n, g.DenseOp):
            ci, co = n.features
            params[n.name] = {"w": jnp.asarray(rng.standard_normal(
                (ci, co), dtype=np.float32) * scale)}
            if n.bias:
                params[n.name]["b"] = jnp.zeros((co,), jnp.float32)
    return params


# ---------------------------------------------------------------------------
# the rewrite rules

def test_residual_add_folds_into_conv():
    gph = _tiny_residual()
    fg, fmap = fuse_graph(gph)
    assert fmap == {"c1": "add:sum"}
    assert [n.name for n in fg.nodes] == ["stem", "c1"]
    c1 = fg.node("c1")
    assert c1.spec.fused_add == "add_relu"      # add's ReLU absorbed
    assert c1.inputs == ("stem", "stem")        # shortcut as 2nd operand
    assert fg.output == "c1"                    # output follows the fold
    assert fg.shapes["c1"] == gph.shapes["sum"]


def test_conv_pool_folds_into_conv():
    gph = _conv_pool()
    fg, fmap = fuse_graph(gph)
    assert fmap == {"c0": "pool:pool"}
    c0 = fg.node("c0")
    assert c0.spec.fused_pool == ("max", 2, 2, 2, 2, 0, 0)
    assert fg.shapes["c0"] == gph.shapes["pool"]    # pooled final_shape
    assert fg.output == "c0"


def test_resnet_like_fuses_add_and_pool():
    """Acceptance: the pass folds >= 1 residual add AND >= 1 conv->pool
    chain out of resnet_like (11 IR nodes -> 8, three fewer launches)."""
    gg = resnet_like(num_classes=4).graph((1, 16, 16, 3))
    fg, fmap = fuse_graph(gg)
    kinds = [v.split(":")[0] for v in fmap.values()]
    assert kinds.count("add") >= 1 and kinds.count("pool") >= 1
    assert len(fg) == len(gg) - len(fmap)
    assert fmap == {"stem": "pool:pool", "b1c2": "add:b1add",
                    "b2proj": "add:b2add"}


def test_multi_consumer_and_non_conv_producers_do_not_fuse():
    # stem feeds both the add AND c1: folding it would orphan c1's input
    b = GraphBuilder((1, 8, 8, 3))
    stem = b.conv("stem", "input", 3, 4, epilogue="bias")
    c1 = b.conv("c1", stem, 3, 4, epilogue="bias_relu")  # relu epilogue
    b.add("sum", (stem, c1))
    fg, fmap = fuse_graph(b.graph())
    # c1 has a relu epilogue (not none/bias) and stem has two consumers:
    # neither leg is fusable
    assert fmap == {} and fg is b.graph() or len(fg) == 3

    # fire_like's avg pool consumes a CONCAT, not a conv: no pool fold
    gg = fire_like(num_classes=4).graph((1, 16, 16, 3))
    _, fmap2 = fuse_graph(gg)
    assert not any(v.startswith("pool") for v in fmap2.values())


def test_fused_convspec_cache_keys_are_distinct():
    base = ConvSpec((1, 8, 8, 4), (3, 3, 4, 8), epilogue="bias")
    fadd = dataclasses.replace(base, fused_add="add")
    faddr = dataclasses.replace(base, fused_add="add_relu")
    fpool = dataclasses.replace(base,
                                fused_pool=("max", 2, 2, 2, 2, 0, 0))
    keys = {base.key(), fadd.key(), faddr.key(), fpool.key()}
    assert len(keys) == 4
    assert fadd.key().endswith("-fadd")
    assert faddr.key().endswith("-faddrelu")
    assert fpool.key().endswith("-fpoolmax2x2s2x2p0x0")
    # and the fused spec round-trips back to the base one
    assert fadd.unfused().key() == base.key()
    assert fpool.unfused().key() == base.key()


# ---------------------------------------------------------------------------
# capability negotiation

def test_fusion_is_capability_negotiated():
    spec = ConvSpec((1, 8, 8, 4), (3, 3, 4, 8), epilogue="bias",
                    fused_add="add")
    # every non-epilogue-fusing executor gets add/pool for free (XLA
    # epilogue); the Pallas fused executor opts in per geometry
    assert "add" in executors.get("lax").fusions(spec)
    assert "add" in executors.get("cuconv_pallas").fusions(spec)
    assert executors.supporting(spec)
    p = plan(spec)
    assert p.algorithm in executors.supporting(spec)

    # an overlapping pool window is NOT in the Pallas executor's fused
    # vocabulary (window must equal stride, zero pad) — the spec still
    # plans, via executors that run the pool as an XLA epilogue
    overlap = ConvSpec((1, 9, 9, 4), (3, 3, 4, 8), epilogue="bias",
                       fused_pool=("max", 3, 3, 2, 2, 0, 0))
    assert "pool" not in executors.get("cuconv_pallas").fusions(overlap)
    assert not executors.get("cuconv_pallas").supports(overlap)[0]
    assert "lax" in executors.supporting(overlap)


def test_fusion_verdict_gates_rewrite(tmp_path, monkeypatch):
    """A persisted tune="full" measurement saying the fusion LOSES keeps
    the graph unfused; unmeasured specs fuse optimistically."""
    gph = _tiny_residual()
    fg, fmap = fuse_graph(gph)
    assert fmap            # optimistic without a verdict
    fused_spec = fg.node("c1").spec
    backend = jax.default_backend()
    key = autotune._key(fused_spec, backend)
    entry = dict(autotune._STORE.get(key) or
                 {"schema": autotune.AUTOTUNE_SCHEMA})
    try:
        entry["fusion"] = {"wins": False, "fused_us": 2.0,
                           "unfused_us": 1.0}
        autotune._STORE.put(key, entry)
        assert autotune.fusion_verdict(fused_spec, backend) is False
        fg2, fmap2 = fuse_graph(gph)
        assert fmap2 == {} and len(fg2) == 3
    finally:
        entry.pop("fusion", None)
        autotune._STORE.put(key, entry)


def test_measure_fusion_persists_verdict():
    spec = ConvSpec((1, 8, 8, 3), (3, 3, 3, 4), epilogue="bias",
                    fused_add="add")
    before = autotune.MEASURE_STATS["fusion_sweeps"]
    got = autotune.measure_fusion(spec, repeats=1, force=True)
    assert got in (True, False)
    assert autotune.MEASURE_STATS["fusion_sweeps"] == before + 1
    assert autotune.fusion_verdict(spec) is got
    with pytest.raises(ValueError):
        autotune.measure_fusion(spec.unfused())


# ---------------------------------------------------------------------------
# planned-program numerics (the property the pass must preserve)

@pytest.mark.parametrize("precision,tol", [(None, 2e-5), ("bf16", 4e-2)])
def test_resnet_fused_matches_unfused(rng, precision, tol):
    m = resnet_like(num_classes=4)
    gg = m.graph((2, 16, 16, 3))
    params = _params_for(gg, rng)
    gpf = m.graph_plan((2, 16, 16, 3), precision=precision)
    gpu = m.graph_plan((2, 16, 16, 3), precision=precision, fuse=False)
    assert gpf.fused and not gpu.fused
    assert len(gpf.graph) < len(gpu.graph)      # fewer kernel launches
    for batch in range(3):                       # property: random draws
        x = jnp.asarray(rng.standard_normal((2, 16, 16, 3),
                                            dtype=np.float32))
        yf = np.asarray(gpf.run(x, params), np.float32)
        yu = np.asarray(gpu.run(x, params), np.float32)
        np.testing.assert_allclose(yf, yu, rtol=tol, atol=tol)


def test_forced_fused_kernel_matches_reference(rng):
    """The Pallas fused kernel itself (addend + in-VMEM pool), forced on
    every node of a residual+pool graph, matches the unfused program."""
    b = GraphBuilder((1, 8, 8, 4))
    c0 = b.conv("c0", "input", 3, 8)
    c1 = b.conv("c1", c0, 3, 8, epilogue="bias")
    s = b.add("sum", (c0, c1), activation="relu")
    b.pool("pool", s, kind="max", window=2)
    gph = b.graph()
    params = _params_for(gph, rng)
    gpf = plan_graph(gph, force="cuconv_pallas", use_cache=False)
    assert set(gpf.fused) == {"c1"}     # sum folds into c1; pool then
    # consumes a conv that already fused an add -> stays a PoolOp node
    gpu = plan_graph(gph, force="cuconv_pallas", use_cache=False,
                     fuse=False)
    x = jnp.asarray(rng.standard_normal((1, 8, 8, 4), dtype=np.float32))
    np.testing.assert_allclose(np.asarray(gpf.run(x, params)),
                               np.asarray(gpu.run(x, params)),
                               rtol=1e-5, atol=1e-5)


def test_forced_pool_fusion_kernel(rng):
    gph = _conv_pool()
    params = _params_for(gph, rng)
    gpf = plan_graph(gph, force="cuconv_pallas", use_cache=False)
    assert gpf.fused == {"c0": "pool:pool"}
    x = jnp.asarray(rng.standard_normal((2, 8, 8, 3), dtype=np.float32))
    y = gpf.run(x, params)
    ref = ops.pool2d(
        ops.cuconv_fused(x, params["c0"]["w"], padding=(1, 1),
                         bias=params["c0"]["b"], activation="relu"),
        "max", (2, 2), (2, 2))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# plan/provenance surfaces

def test_explain_reports_fused_provenance():
    m = resnet_like(num_classes=4)
    txt = m.graph_plan((1, 16, 16, 3)).explain()
    assert "fused[pool]=pool" in txt
    assert "fused[add]=b1add" in txt
    assert "fused[add]=b2add" in txt
    # the unfused program shows none
    assert "fused[" not in m.graph_plan((1, 16, 16, 3), fuse=False).explain()


def test_graph_cache_hit_with_fusion_is_zero_resolution():
    from repro.core import convspec as cs
    gph = _tiny_residual()
    gp1 = plan_graph(gph)
    assert gp1.source == "resolved" and gp1.fused
    g.clear_cache()
    cs.reset_plan_stats()
    gp2 = plan_graph(gph)
    assert gp2.source == "graph_cache"
    assert cs.PLAN_STATS["resolutions"] == 0
    assert gp2.fused == gp1.fused


def test_warmup_compiles_fused_nodes(rng):
    gp = plan_graph(_tiny_residual(), use_cache=False)
    stats = gp.warmup()
    assert {r["node"] for r in stats["nodes"]} == {"stem", "c1"}
    keys = {r["node"]: r["key"] for r in stats["nodes"]}
    assert keys["c1"].endswith("-faddrelu")     # tuned under the fused key
