"""Grouped/depthwise convolutions: ConvSpec.groups through the planner,
property-tested against ``lax.conv_general_dilated(feature_group_count)``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # deterministic fallback; see _hypothesis_compat
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import convspec as cs
from repro.core import cuconv as cc
from repro.core import executors as ex


@pytest.fixture(autouse=True)
def _hermetic_autotune_cache(tmp_path, monkeypatch):
    from repro.core import autotune
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "cache"))
    autotune.clear_cache()
    yield
    autotune.clear_cache()


def _lax_grouped(x, w, stride, padding, groups):
    kh, kw = w.shape[0], w.shape[1]
    ph, pw = cs.normalize_pad(padding, kh, kw)
    return jax.lax.conv_general_dilated(
        x, w, window_strides=cs.normalize_stride(stride),
        padding=((ph, ph), (pw, pw)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


grouped_shapes = st.tuples(
    st.integers(1, 2),                 # N
    st.integers(5, 12),                # H (=W)
    st.sampled_from([1, 3, 5]),        # K
    st.integers(1, 5),                 # C per group
    st.sampled_from([1, 2, 4]),        # groups
    st.integers(1, 3),                 # M per group
    st.integers(1, 2),                 # stride
)


@settings(max_examples=40, deadline=None)
@given(grouped_shapes, st.sampled_from(["same", "valid", 1]),
       st.integers(0, 2**31 - 1))
def test_grouped_conv2d_matches_feature_group_count(shape_tuple, padding,
                                                    seed):
    """conv2d(..., groups=g) == the library grouped conv, across
    stride / padding / groups (depthwise included via C_per_group=1)."""
    N, H, K, cpg, groups, mpg, s = shape_tuple
    if padding == "valid" and H < K:
        s, padding = 1, "same"
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(N, H, H, cpg * groups)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, cpg, groups * mpg)), jnp.float32)
    got = cc.conv2d(x, w, s, padding, groups=groups)
    want = _lax_grouped(x, w, s, padding, groups)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


@settings(max_examples=15, deadline=None)
@given(grouped_shapes, st.integers(0, 2**31 - 1))
def test_grouped_epilogue_matches_reference(shape_tuple, seed):
    """bias+ReLU rides a grouped conv exactly like an ungrouped one."""
    N, H, K, cpg, groups, mpg, s = shape_tuple
    rng = np.random.default_rng(seed)
    m = groups * mpg
    x = jnp.asarray(rng.normal(size=(N, H, H, cpg * groups)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, K, cpg, m)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(m,)), jnp.float32)
    got = cc.conv2d(x, w, s, "same", groups=groups, bias=b,
                    activation="relu")
    want = jax.nn.relu(_lax_grouped(x, w, s, "same", groups) + b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# planner policy for grouped specs

def _dw_spec(c=8, h=8, k=3):
    return cs.ConvSpec((1, h, h, c), (k, k, 1, c), (1, 1),
                       ((k - 1) // 2,) * 2, "float32", "none", c)


def test_grouped_spec_validation():
    with pytest.raises(ValueError, match="groups"):
        cs.ConvSpec((1, 8, 8, 8), (3, 3, 1, 8), groups=0)
    with pytest.raises(ValueError, match="channel mismatch"):
        cs.ConvSpec((1, 8, 8, 8), (3, 3, 2, 8), groups=8)
    with pytest.raises(ValueError, match="divisible"):
        cs.ConvSpec((1, 8, 8, 8), (3, 3, 2, 6), groups=4)
    # ungrouped key shape is unchanged (old persisted entries stay valid)
    assert "-g" not in cs.ConvSpec((1, 8, 8, 4), (3, 3, 4, 4),
                                   padding=(1, 1)).key()
    assert _dw_spec().key().endswith("-g8")


def test_grouped_plan_routes_to_library_conv():
    spec = _dw_spec()
    p = cs.plan(spec)
    assert (p.algorithm, p.source) == ("lax", "heuristic")
    assert "feature_group_count" in p.reason
    for name in ex.names():
        ok, why = cs.supports(name, spec)
        assert ok == (name == "lax"), name


def test_forcing_ungrouped_executor_on_grouped_spec_raises():
    """Forcing an executor that cannot run grouped specs is a loud,
    named error at plan time — not a silent fallback to a different
    algorithm than the caller demanded, and not a failure deep inside
    the kernel."""
    spec = _dw_spec()
    with pytest.raises(ValueError) as err:
        cs.plan(spec, force="cuconv_pallas")
    msg = str(err.value)
    assert "cuconv_pallas" in msg             # names the executor
    assert spec.key() in msg                  # names the spec
    assert "groups" in msg
    with pytest.raises(ValueError, match="winograd"):
        cs.plan(spec, force="winograd")
    # the one grouped-capable executor still forces cleanly
    fp = cs.plan(spec, force="lax")
    assert (fp.algorithm, fp.source) == ("lax", "forced")


def test_grouped_measure_and_heuristic_on_tpu_backend(rng):
    """Measured mode and the TPU heuristic both land on the library conv
    (the only supported executor) for grouped specs."""
    from repro.core import autotune
    spec = _dw_spec()
    assert tuple(autotune.default_candidates(spec)) == ("lax",)
    assert cs.plan(spec, backend="tpu").algorithm == "lax"
    x = jnp.asarray(rng.normal(size=spec.in_shape), jnp.float32)
    w = jnp.asarray(rng.normal(size=spec.filter_shape), jnp.float32)
    best = autotune.measure_algorithm(x, w, repeats=1, groups=spec.groups)
    assert best == "lax"
    assert autotune.cached_best(spec) == "lax"


@pytest.mark.parametrize("hw,k,m,c,groups", [
    (28, 3, 128, 128, 128),            # MobileNet v1 depthwise stage
    (14, 3, 256, 256, 256),
])
def test_real_mobilenet_depthwise_configs_plan_and_run(rng, hw, k, m, c,
                                                       groups):
    from repro.configs.cnn_paper import MOBILENET_DW
    assert (hw, k, m, c, groups) in MOBILENET_DW
    x = jnp.asarray(rng.normal(size=(1, hw, hw, c)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, k, c // groups, m)), jnp.float32)
    spec = cs.ConvSpec.for_conv(x, w, 1, "same", groups=groups)
    p = cs.plan(spec)
    assert p.algorithm == "lax"
    got = p(x, w)
    want = _lax_grouped(x, w, 1, "same", groups)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_unknown_activation_raises():
    """for_conv must not silently drop unknown activations (the old
    behaviour planned epilogue 'none' for activation='gelu')."""
    x = jnp.zeros((1, 8, 8, 4), jnp.float32)
    w = jnp.zeros((3, 3, 4, 4), jnp.float32)
    with pytest.raises(ValueError, match="gelu"):
        cs.ConvSpec.for_conv(x, w, 1, "same", activation="gelu")
    with pytest.raises(ValueError, match="activation"):
        cc.conv2d(x, w, 1, "same", bias=jnp.zeros((4,)),
                  activation="swish")
    # the accepted spellings still work
    assert cs.ConvSpec.for_conv(x, w, activation="relu").epilogue == "relu"
    assert cs.ConvSpec.for_conv(x, w, activation="none").epilogue == "none"
    assert cs.ConvSpec.for_conv(x, w, activation=None).epilogue == "none"
