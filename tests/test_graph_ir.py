"""Typed operator-IR graph API: DAG validation, whole-network numerics
(residual / pool / concat / grouped conv / head), schema-versioned cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import convspec as cs
from repro.core import cuconv as cc
from repro.core import graph as g
from repro.core.graph import (AddOp, ConcatOp, ConvOp, DenseOp, GapOp,
                              Graph, GraphBuilder, PoolOp)
from repro.models.cnn import fire_like, mobilenet_like, resnet_like
from repro.serve.cnn import CnnServeEngine, ImageRequest


@pytest.fixture(autouse=True)
def _hermetic_caches(tmp_path, monkeypatch):
    """Point both persisted plan stores (autotune.json, graphplans.json)
    at an empty per-test dir so other runs on this machine can't leak."""
    from repro.core import autotune
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "cache"))
    autotune.clear_cache()
    g.clear_cache()
    yield
    autotune.clear_cache()
    g.clear_cache()


def _spec(in_shape, k, m, stride=1, epilogue="none", groups=1):
    c = in_shape[3]
    return cs.ConvSpec(in_shape, (k, k, c // groups, m),
                       (stride, stride), ((k - 1) // 2,) * 2,
                       "float32", epilogue, groups)


# ---------------------------------------------------------------------------
# DAG construction / shape validation

def test_graph_rejects_duplicate_node_name():
    spec = _spec((1, 8, 8, 3), 3, 4)
    with pytest.raises(ValueError, match="duplicate"):
        Graph((ConvOp("a", ("input",), spec),
               GapOp("a", ("a",))), (1, 8, 8, 3))


def test_graph_rejects_undefined_and_forward_edges():
    spec = _spec((1, 8, 8, 3), 3, 4)
    with pytest.raises(ValueError, match="undefined edge"):
        Graph((ConvOp("a", ("ghost",), spec),), (1, 8, 8, 3))
    # a forward reference is the same error: nodes are topologically
    # ordered by construction, so cycles cannot be expressed at all
    with pytest.raises(ValueError, match="undefined edge"):
        Graph((AddOp("sum", ("a", "sum")),
               ConvOp("a", ("input",), spec)), (1, 8, 8, 3))


def test_graph_rejects_shape_mismatches():
    b = GraphBuilder((1, 8, 8, 3))
    y = b.conv("c1", "input", 3, 4)
    z = b.conv("c2", y, 3, 8)                 # different channel count
    with pytest.raises(ValueError, match="add node"):
        b.add("bad", (y, z))
    with pytest.raises(ValueError, match="expects input shape"):
        Graph((ConvOp("c", ("input",), _spec((1, 4, 4, 3), 3, 4)),),
              (1, 8, 8, 3))
    with pytest.raises(ValueError, match="dense node"):
        b2 = GraphBuilder((1, 8, 8, 3))
        gp = b2.gap("g", "input")
        b2.nodes.append(DenseOp("d", (gp,), (99, 5)))
        b2.graph()


def test_graph_rejects_bad_concat_and_pool():
    b = GraphBuilder((1, 8, 8, 3))
    a = b.conv("a", "input", 3, 4)
    d = b.conv("d", "input", 3, 4, stride=2)  # halved spatial dims
    with pytest.raises(ValueError, match="concat node"):
        b.concat("cat", (a, d))
    with pytest.raises(ValueError, match="empty"):
        b.pool("p", a, window=16)
    with pytest.raises(ValueError, match="kind"):
        PoolOp("p", ("input",), "median")


def test_graph_output_selection_and_properties():
    b = GraphBuilder((2, 8, 8, 3))
    y = b.conv("c1", "input", 3, 4)
    b.gap("gap", y)
    gph = b.graph(output="c1")
    assert gph.output == "c1"
    assert gph.out_shape == (2, 8, 8, 4)
    assert [n.name for n in gph.conv_nodes] == ["c1"]
    with pytest.raises(ValueError, match="not a node"):
        b.graph(output="input")
    with pytest.raises(ValueError, match="not a node"):
        b.graph(output="nope")


def test_signature_is_schema_versioned_and_structure_sensitive():
    def build(activation):
        b = GraphBuilder((1, 8, 8, 3))
        y = b.conv("c1", "input", 3, 4, epilogue="bias")
        b.add("sum", (y, y), activation=activation)
        return b.graph()
    assert build("relu").signature() == build("relu").signature()
    assert build("relu").signature() != build("none").signature()
    blob = "|".join([f"v{g.GRAPH_SCHEMA}", f"in{(1, 8, 8, 3)}",
                     "out:sum"] + [n.descriptor()
                                   for n in build("relu").nodes])
    import hashlib
    assert build("relu").signature() == hashlib.sha1(
        blob.encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# IR execution numerics vs a plain-lax reference

def _cb(p, x, stride=1, relu=True, groups=1):
    """conv + bias (+ relu) reference, library kernels only."""
    y = cc.conv_lax(x, p["w"], stride, "same", groups=groups) + p["b"]
    return jax.nn.relu(y) if relu else y


def _maxpool_ref(x, k=2, s=2):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, 1), (1, s, s, 1), "VALID")


def _avgpool_ref(x, k=2, s=2):
    return jax.lax.reduce_window(x, jnp.zeros((), x.dtype), jax.lax.add,
                                 (1, k, k, 1), (1, s, s, 1),
                                 "VALID") / (k * k)


def test_residual_add_graph_matches_lax(rng):
    b = GraphBuilder((2, 10, 10, 3))
    y = b.conv("stem", "input", 3, 6)
    z = b.conv("c1", y, 3, 6)
    z = b.conv("c2", z, 3, 6, epilogue="bias")
    b.add("sum", (y, z), activation="relu")
    gp = g.plan_graph(b.graph())
    params = {n.name: {"w": jnp.asarray(
        rng.normal(size=n.spec.filter_shape), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(n.spec.filter_shape[3],)),
                         jnp.float32)}
        for n in gp.graph.conv_nodes}
    x = jnp.asarray(rng.normal(size=(2, 10, 10, 3)), jnp.float32)
    got = gp.run(x, params)
    stem = _cb(params["stem"], x)
    want = jax.nn.relu(stem + _cb(params["c2"],
                                  _cb(params["c1"], stem), relu=False))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_concat_graph_matches_lax(rng):
    b = GraphBuilder((1, 8, 8, 4))
    s = b.conv("squeeze", "input", 1, 3)
    e1 = b.conv("e1", s, 1, 5)
    e3 = b.conv("e3", s, 3, 5)
    b.concat("cat", (e1, e3))
    gp = g.plan_graph(b.graph())
    params = {n.name: {"w": jnp.asarray(
        rng.normal(size=n.spec.filter_shape), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(n.spec.filter_shape[3],)),
                         jnp.float32)}
        for n in gp.graph.conv_nodes}
    x = jnp.asarray(rng.normal(size=(1, 8, 8, 4)), jnp.float32)
    got = gp.run(x, params)
    sq = _cb(params["squeeze"], x)
    want = jnp.concatenate([_cb(params["e1"], sq),
                            _cb(params["e3"], sq)], axis=-1)
    assert got.shape == (1, 8, 8, 10)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_pool_nodes_match_reduce_window(rng):
    b = GraphBuilder((2, 12, 12, 5))
    m = b.pool("mx", "input", kind="max", window=2)
    b.pool("av", m, kind="avg", window=3, stride=1, padding=1)
    gp = g.plan_graph(b.graph())
    x = jnp.asarray(rng.normal(size=(2, 12, 12, 5)), jnp.float32)
    got = gp.run(x, {})
    want = jax.lax.reduce_window(
        _maxpool_ref(x), jnp.zeros(()), jax.lax.add,
        (1, 3, 3, 1), (1, 1, 1, 1),
        ((0, 0), (1, 1), (1, 1), (0, 0))) / 9
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_run_rejects_missing_bias(rng):
    b = GraphBuilder((1, 6, 6, 3))
    b.conv("c", "input", 3, 4)                # bias_relu epilogue
    gp = g.plan_graph(b.graph())
    x = jnp.zeros((1, 6, 6, 3), jnp.float32)
    with pytest.raises(ValueError, match="bias"):
        gp.run(x, {"c": {"w": jnp.zeros((3, 3, 3, 4))}})


def test_explain_covers_every_ir_node_kind(rng):
    model = resnet_like()
    gp = model.graph_plan((1, 32, 32, 3))
    txt = gp.explain()
    assert len(txt.splitlines()) == len(gp.graph) + 1
    for name in ("stem", "pool", "b1add", "gap", "head"):
        assert name in txt
    mob = mobilenet_like().graph_plan((1, 32, 32, 3))
    assert " g16 " in mob.explain()           # depthwise marker


# ---------------------------------------------------------------------------
# whole real networks: one planned program end to end

def _resnet_ref(params, x):
    y = _cb(params["stem"], x)
    y = _maxpool_ref(y)
    z = _cb(params["b1c2"], _cb(params["b1c1"], y), relu=False)
    y = jax.nn.relu(y + z)
    z = _cb(params["b2c2"], _cb(params["b2c1"], y, stride=2), relu=False)
    p = _cb(params["b2proj"], y, stride=2, relu=False)
    y = jax.nn.relu(p + z)
    y = y.mean(axis=(1, 2))
    return y @ params["head"]["w"] + params["head"]["b"]


def _mobilenet_ref(params, x):
    y = _cb(params["stem"], x, stride=2)
    y = _cb(params["dw1"], y, groups=16)
    y = _cb(params["pw1"], y)
    y = _cb(params["dw2"], y, stride=2, groups=32)
    y = _cb(params["pw2"], y)
    y = y.mean(axis=(1, 2))
    return y @ params["head"]["w"] + params["head"]["b"]


def _fire_ref(params, x):
    y = _cb(params["stem"], x, stride=2)
    sq = _cb(params["squeeze"], y)
    y = jnp.concatenate([_cb(params["expand1"], sq),
                         _cb(params["expand3"], sq)], axis=-1)
    y = _avgpool_ref(y)
    y = y.mean(axis=(1, 2))
    return y @ params["head"]["w"] + params["head"]["b"]


@pytest.mark.parametrize("mk,ref", [(resnet_like, _resnet_ref),
                                    (mobilenet_like, _mobilenet_ref),
                                    (fire_like, _fire_ref)])
def test_model_forward_matches_lax_reference(rng, mk, ref):
    model = mk(num_classes=5)
    params = model.init(jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(2, 32, 32, 3)), jnp.float32)
    y = jax.jit(lambda p, xx: model.apply(p, xx))(params, x)
    assert y.shape == (2, 5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref(params, x)),
                               rtol=3e-4, atol=3e-4, err_msg=model.name)


@pytest.mark.parametrize("mk", [resnet_like, mobilenet_like])
def test_acceptance_whole_network_planned_once(rng, mk):
    """Acceptance: residual add, pooling, depthwise/grouped convs and
    the head all execute inside ONE GraphPlan program — zero plan()
    resolutions after warmup."""
    model = mk()
    params = model.init(jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(1, 32, 32, 3)), jnp.float32)
    gp = model.graph_plan((1, 32, 32, 3))
    gp.warmup()
    cs.reset_plan_stats()
    for _ in range(3):
        y = model.apply(params, x)            # eager: re-enters apply
    assert cs.PLAN_STATS["resolutions"] == 0
    assert y.shape == (1, 10)


def test_mobilenet_grouped_nodes_planned_via_feature_group_count():
    model = mobilenet_like()
    gp = model.graph_plan((1, 32, 32, 3))
    dw = {n.name: gp.conv_plans[n.name] for n in gp.graph.conv_nodes
          if n.spec.groups != 1}
    assert set(dw) == {"dw1", "dw2"}
    for name, p in dw.items():
        assert p.algorithm == "lax", name
        assert f"-g{p.spec.groups}" in p.spec.key()


def test_serve_engine_over_resnet_like(rng):
    """The IR program is bucketable: a mixed request stream served
    through CnnServeEngine matches the reference with zero re-plans."""
    model = resnet_like(num_classes=4)
    params = model.init(jax.random.PRNGKey(0))
    eng = CnnServeEngine(model, params, (32, 32, 3), buckets=(1, 2))
    eng.warmup()
    reqs = [ImageRequest(rid=i, images=rng.normal(
        size=(n, 32, 32, 3)).astype(np.float32))
        for i, n in enumerate([1, 3, 2])]
    for r in reqs:
        eng.submit(r)
    cs.reset_plan_stats()
    done = eng.run()
    assert cs.PLAN_STATS["resolutions"] == 0
    for r in done:
        for i in range(r.images.shape[0]):
            want = _resnet_ref(params, jnp.asarray(r.images[i:i + 1]))
            np.testing.assert_allclose(r.out[i], np.asarray(want)[0],
                                       rtol=3e-4, atol=3e-4,
                                       err_msg=f"req {r.rid} image {i}")


# ---------------------------------------------------------------------------
# schema-versioned persisted cache

def _tiny_ir():
    b = GraphBuilder((1, 8, 8, 3))
    y = b.conv("stem", "input", 3, 4)
    z = b.conv("c1", y, 3, 4, epilogue="bias")
    b.add("sum", (y, z), activation="relu")
    return b.graph()


def test_ir_cache_roundtrip_zero_resolutions():
    gph = _tiny_ir()
    gp1 = g.plan_graph(gph)
    assert gp1.source == "resolved"
    entry = g._STORE.get(g._graph_key(gph, gp1.backend))
    assert entry["schema"] == g.GRAPH_SCHEMA
    assert set(entry["algorithms"]) == {"stem", "c1"}     # keyed by name
    g.clear_cache()                        # simulate a fresh process
    cs.reset_plan_stats()
    gp2 = g.plan_graph(gph)
    assert gp2.source == "graph_cache"
    assert cs.PLAN_STATS["resolutions"] == 0
    assert {n: p.algorithm for n, p in gp2.conv_plans.items()} == \
        {n: p.algorithm for n, p in gp1.conv_plans.items()}


@pytest.mark.parametrize("entry", [
    {"algorithms": ["lax", "lax"]},                      # v1 positional
    {"schema": 99, "algorithms": {"stem": "lax", "c1": "lax"}},
    {"schema": 2, "algorithms": {"stem": "lax"}},        # wrong node set
    {"schema": 2, "algorithms": {"stem": "lax", "c1": "conv9000"}},
    ["lax", "lax"],
])
def test_unversioned_or_mismatched_cache_entries_dropped(entry):
    """IR-era decoding must never misread legacy positional entries (or
    vice versa): anything without the exact current schema re-resolves."""
    gph = _tiny_ir()
    backend = jax.default_backend()
    g._STORE.put(g._graph_key(gph, backend), entry)
    gp = g.plan_graph(gph)
    assert gp.source == "resolved"
    # and the re-resolve re-persisted a current-schema entry
    assert g._STORE.get(g._graph_key(gph, backend))["schema"] == \
        g.GRAPH_SCHEMA


def test_chain_per_layer_epilogues():
    """A classifier chain can plan its last conv as plain `bias` while
    hidden layers keep bias_relu (and lowering preserves it)."""
    layers = [(3, 3, 8, 1), (1, 1, 5, 1)]
    gph = g.ConvGraph.chain(layers, (1, 8, 8, 3),
                            epilogue=("bias_relu", "bias"))
    assert [s.epilogue for s in gph.nodes] == ["bias_relu", "bias"]
    ir = gph.to_ir()
    assert [n.spec.epilogue for n in ir.conv_nodes] == ["bias_relu", "bias"]
    with pytest.raises(ValueError, match="epilogue sequence"):
        g.ConvGraph.chain(layers, (1, 8, 8, 3), epilogue=("bias",))


def test_chain_and_ir_share_cache_namespace():
    """ConvGraph.chain callers and IR callers hit the SAME persisted
    entry: the chain's signature is its lowered IR's signature."""
    layers = [(3, 3, 4, 1)]
    chain = g.ConvGraph.chain(layers, (1, 8, 8, 3))
    assert chain.signature() == chain.to_ir().signature()
    g.plan_graph(chain)
    g.clear_cache()
    cs.reset_plan_stats()
    gp = g.plan_graph(chain.to_ir())
    assert gp.source == "graph_cache"
    assert cs.PLAN_STATS["resolutions"] == 0


def test_reset_plan_stats_helper():
    cs.plan(cs.ConvSpec((1, 4, 4, 2), (1, 1, 2, 2)))
    assert cs.PLAN_STATS["resolutions"] > 0
    discarded = cs.reset_plan_stats()
    assert discarded > 0
    assert cs.PLAN_STATS["resolutions"] == 0


# ---------------------------------------------------------------------------
# graph-wide precision policy (bf16 end to end)

def test_precision_policy_spellings_and_overrides():
    pol = g.PrecisionPolicy("bf16", overrides={"stem": "fp32"})
    assert pol.default == "bfloat16"
    assert pol.dtype_for("stem") == "float32"
    assert pol.dtype_for("anything_else") == "bfloat16"
    assert g.PrecisionPolicy.of("bf16") == g.PrecisionPolicy("bfloat16")
    assert g.PrecisionPolicy.of(None).default == "float32"
    assert pol.key() != g.PrecisionPolicy("bf16").key()
    with pytest.raises(ValueError, match="dtype"):
        g.PrecisionPolicy("not_a_dtype")


def test_precision_policy_lands_in_node_specs_and_signature():
    """The policy becomes each conv node's ConvSpec.dtype, with
    per-node overrides honored — and the graph signature (the persisted
    cache key) is precision-distinct."""
    def build(precision):
        b = GraphBuilder((1, 8, 8, 3), precision)
        y = b.conv("stem", "input", 3, 4)
        b.conv("c1", y, 3, 4, epilogue="bias")
        return b.graph()
    g32 = build("float32")
    gbf = build(g.PrecisionPolicy("bf16", overrides={"stem": "fp32"}))
    assert [n.spec.dtype for n in g32.conv_nodes] == ["float32", "float32"]
    assert [n.spec.dtype for n in gbf.conv_nodes] == ["float32", "bfloat16"]
    assert g32.signature() != gbf.signature()
    assert "-bfloat16-" in gbf.conv_nodes[1].spec.key()
    # a typo'd override would silently run the node in the default
    # dtype — the builder rejects overrides naming no node
    with pytest.raises(ValueError, match="stem0"):
        build(g.PrecisionPolicy("bf16", overrides={"stem0": "fp32"}))


def test_acceptance_resnet_bf16_plans_warms_serves(rng):
    """Acceptance: a full resnet_like network plans, warms up and serves
    through CnnServeEngine under PrecisionPolicy("bf16") with fp32
    accumulation — numerics within bf16 tolerance of the fp32 path,
    cache keys dtype-distinct (no fp32/bf16 collisions)."""
    from repro.core import executors as ex
    model = resnet_like(num_classes=4)
    params = model.init(jax.random.PRNGKey(0))

    gp32 = model.graph_plan((1, 32, 32, 3))
    gpbf = model.graph_plan((1, 32, 32, 3), precision="bf16")
    assert all(n.spec.dtype == "bfloat16" for n in gpbf.graph.conv_nodes)
    # every chosen executor declares bf16 + fp32 accumulation
    for p in gpbf.conv_plans.values():
        assert "bfloat16" in ex.get(p.algorithm).dtypes
        assert ex.get(p.algorithm).accum == "float32"
    # dtype-distinct persisted keys: both entries coexist in the store
    assert gp32.graph.signature() != gpbf.graph.signature()
    assert g._STORE.get(g._graph_key(gp32.graph, gp32.backend)) is not None
    assert g._STORE.get(g._graph_key(gpbf.graph, gpbf.backend)) is not None
    assert "bfloat16" in gpbf.explain() and "bfloat16" not in gp32.explain()
    gpbf.warmup()

    eng = CnnServeEngine(model, params, (32, 32, 3), buckets=(1, 2),
                         precision="bf16")
    eng.warmup()
    reqs = [ImageRequest(rid=i, images=rng.normal(
        size=(n, 32, 32, 3)).astype(np.float32))
        for i, n in enumerate([1, 3, 2])]
    for r in reqs:
        eng.submit(r)
    cs.reset_plan_stats()
    done = eng.run()
    assert cs.PLAN_STATS["resolutions"] == 0      # warm engine: no re-plans
    for r in done:
        for i in range(r.images.shape[0]):
            want = _resnet_ref(params, jnp.asarray(r.images[i:i + 1]))
            np.testing.assert_allclose(
                r.out[i].astype(np.float32), np.asarray(want)[0],
                rtol=4e-2, atol=4e-2, err_msg=f"req {r.rid} image {i}")


def test_bf16_measured_warmup_uses_dtype_distinct_autotune_keys():
    """warmup(measure=True) on a bf16 graph records winners under bf16
    spec keys — an fp32 sweep can never serve (or clobber) them."""
    from repro.core import autotune
    b = GraphBuilder((1, 6, 6, 3), "bf16")
    b.conv("c0", "input", 1, 4)
    gp = g.plan_graph(b.graph())
    gp.warmup(measure=True, repeats=1)
    spec_bf = gp.graph.conv_nodes[0].spec
    assert autotune.cached_best(spec_bf) is not None
    spec_f32 = dataclasses_replace_dtype(spec_bf, "float32")
    assert autotune.cached_best(spec_f32) is None


def dataclasses_replace_dtype(spec, dtype):
    import dataclasses
    return dataclasses.replace(spec, dtype=dtype)
