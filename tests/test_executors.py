"""Executor-registry conformance suite (core/executors.py).

Two invariants, parametrized over EVERY registered executor x dtype:

  (a) numerics — for every spec the executor claims to support, its
      planned execution (epilogue included) matches the fp32 library
      reference within dtype-appropriate tolerance;
  (b) capability honesty — ``plan()`` never selects an executor whose
      declared capabilities don't cover the spec, across forced /
      measured / heuristic / cost tiers and both backends.

Plus the registry API itself: registration, duplicate/unknown errors,
third-party executors participating in negotiation and cache
resolution, and the cheapest-supported cost tier.

CI runs this file as its own matrix step (Pallas interpret mode on
CPU), split by dtype, so kernel-capability regressions fail fast.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune
from repro.core import convspec as cs
from repro.core import cuconv as cc
from repro.core import executors as ex

TOLS = {"float32": dict(rtol=3e-4, atol=3e-4),
        "bfloat16": dict(rtol=3e-2, atol=3e-2)}

DTYPES = ("float32", "bfloat16")


@pytest.fixture(autouse=True)
def _hermetic_autotune_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "cache"))
    autotune.clear_cache()
    yield
    autotune.clear_cache()


# (in_shape, (kh, kw), m, stride, padding, epilogue, groups): small but
# covering every capability axis — kernel size, stride, padding, 1x1,
# epilogue fusion, grouped/depthwise
SWEEP = [
    ((1, 8, 8, 6), (3, 3), 4, (1, 1), (1, 1), "bias_relu", 1),
    ((2, 9, 9, 5), (3, 3), 4, (2, 2), (1, 1), "none", 1),
    ((1, 6, 6, 8), (1, 1), 4, (1, 1), (0, 0), "none", 1),
    ((1, 6, 6, 8), (1, 1), 4, (1, 1), (0, 0), "bias", 1),
    ((1, 7, 7, 4), (5, 5), 3, (1, 1), (2, 2), "bias", 1),
    ((1, 8, 8, 8), (3, 3), 8, (1, 1), (1, 1), "relu", 8),     # depthwise
    ((2, 8, 8, 6), (3, 3), 4, (1, 1), (1, 1), "bias_relu", 2),
]


def _spec(geom, dtype):
    in_shape, (kh, kw), m, stride, padding, epi, groups = geom
    return cs.ConvSpec(in_shape, (kh, kw, in_shape[3] // groups, m),
                       stride, padding, dtype, epi, groups)


def _operands(spec, rng):
    dtype = jnp.dtype(spec.dtype)
    x = jnp.asarray(rng.normal(size=spec.in_shape), jnp.float32) \
        .astype(dtype)
    w = jnp.asarray(rng.normal(size=spec.filter_shape), jnp.float32) \
        .astype(dtype)
    b = (jnp.asarray(rng.normal(size=(spec.filter_shape[3],)), jnp.float32)
         .astype(dtype) if spec.has_bias else None)
    return x, w, b


def _f32_ref(spec, x, w, b):
    """fp32 library reference, epilogue included."""
    y = cc.conv_lax(x.astype(jnp.float32), w.astype(jnp.float32),
                    spec.stride, spec.padding, groups=spec.groups)
    if spec.has_bias:
        y = y + b.astype(jnp.float32)
    if spec.wants_relu:
        y = jax.nn.relu(y)
    return np.asarray(y)


# ---------------------------------------------------------------------------
# (a) numerics conformance: every executor x dtype over its claimed specs

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("name", ex.names())
def test_executor_numerics_conform_to_declared_capabilities(rng, name,
                                                            dtype):
    exe = ex.get(name)
    ran = 0
    for geom in SWEEP:
        spec = _spec(geom, dtype)
        ok, why = exe.supports(spec)
        if not ok:
            continue
        ran += 1
        x, w, b = _operands(spec, rng)
        got = exe.execute(spec, x, w, bias=b)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), _f32_ref(spec, x, w, b),
            err_msg=f"{name} {spec.key()}", **TOLS[dtype])
    if dtype in exe.dtypes:
        assert ran > 0, (f"{name} declares dtype {dtype} but supports "
                         f"no spec in the conformance sweep")
    else:
        assert ran == 0, (f"{name} executed {dtype} specs it does not "
                          f"declare")


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("fused_add", ["add", "add_relu"])
def test_winograd_pallas_fuses_residual_add_in_kernel(rng, dtype,
                                                      fused_add):
    """winograd_pallas declares fusions() = ('add',): the residual
    operand is applied in VMEM after the inverse transform.  Forced on a
    fused-add spec, the planned execution matches conv + add (+ relu)."""
    import dataclasses
    base = _spec(SWEEP[0], dtype)                       # 3x3 s1 bias_relu
    spec = dataclasses.replace(base, epilogue="bias", fused_add=fused_add)
    exe = ex.get("winograd_pallas")
    ok, why = exe.supports(spec)
    assert ok, why
    x, w, b = _operands(spec, rng)
    ad = jnp.asarray(rng.normal(size=spec.out_shape), jnp.float32) \
        .astype(jnp.dtype(dtype))
    p = cs.plan(spec, force="winograd_pallas")
    got = np.asarray(p(x, w, b, addend=ad), np.float32)
    want = _f32_ref(spec, x, w, b) + np.asarray(ad, np.float32)
    if fused_add == "add_relu":
        want = np.maximum(want, 0.0)
    np.testing.assert_allclose(got, want, **TOLS[dtype])


@pytest.mark.parametrize("dtype", DTYPES)
def test_bf16_inputs_accumulate_fp32(rng, dtype):
    """Every executor declares fp32 accumulation; check it holds: a
    reduction long enough to drift under bf16 accumulation stays close
    to the fp32 answer."""
    spec = _spec(((1, 6, 6, 512), (1, 1), 4, (1, 1), (0, 0), "none", 1),
                 dtype)
    x, w, b = _operands(spec, rng)
    want = _f32_ref(spec, x, w, b)
    for name in ex.supporting(spec):
        exe = ex.get(name)
        assert exe.accum == "float32"
        got = np.asarray(exe.execute(spec, x, w), np.float32)
        # C=512 contraction: bf16 accumulation would drift ~0.1 rel;
        # fp32 accumulation stays within input-rounding error
        np.testing.assert_allclose(got, want, err_msg=name, **TOLS[dtype])


# ---------------------------------------------------------------------------
# (b) plan() capability honesty

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("backend", ["cpu", "tpu"])
def test_plan_never_selects_incapable_executor(backend, dtype):
    for geom in SWEEP:
        spec = _spec(geom, dtype)
        p = cs.plan(spec, backend=backend)
        ok, why = ex.get(p.algorithm).supports(spec)
        assert ok, (f"plan chose {p.algorithm} [{p.source}] for "
                    f"{spec.key()} but it declares: {why}")


@pytest.mark.parametrize("dtype", DTYPES)
def test_forced_plans_resolve_or_refuse_loudly(dtype):
    """Forcing any registered executor either lands on a capable
    executor (forced or its declared fallback) or raises a clear error
    (grouped specs with no grouped-capable target)."""
    for geom in SWEEP:
        spec = _spec(geom, dtype)
        for name in ex.names():
            exe = ex.get(name)
            if spec.groups != 1 and not exe.supports_groups:
                with pytest.raises(ValueError, match=name):
                    cs.plan(spec, force=name)
                continue
            p = cs.plan(spec, force=name)
            assert p.source in ("forced", "fallback")
            assert ex.get(p.algorithm).supports(spec)[0]


def test_stale_measured_winner_remeasures_instead_of_short_circuiting(rng):
    """measure_algorithm must not return a persisted winner that is no
    longer registered/capable — it re-sweeps and overwrites the entry."""
    x = jnp.asarray(rng.normal(size=(1, 6, 6, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(1, 1, 4, 3)), jnp.float32)
    spec = cs.ConvSpec.for_conv(x, w, 1, "same")
    autotune.record_best(spec, jax.default_backend(), "gone_executor")
    best = autotune.measure_algorithm(x, w, repeats=1,
                                      candidates=("lax", "cuconv"))
    assert best in ("lax", "cuconv")
    assert autotune.cached_best(spec) == best    # stale entry overwritten


def test_measure_skips_unknown_candidates(rng):
    """An explicit candidate list naming an unregistered plugin times
    the remaining candidates instead of crashing the sweep."""
    x = jnp.asarray(rng.normal(size=(1, 5, 5, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(1, 1, 4, 2)), jnp.float32)
    best = autotune.measure_algorithm(
        x, w, repeats=1, candidates=("unregistered_plugin", "lax"))
    assert best == "lax"


def test_stale_measured_winner_never_misplans():
    """A persisted measured entry naming an executor that cannot run the
    spec (or is no longer registered) is ignored, not served."""
    spec = _spec(SWEEP[1], "float32")               # strided
    autotune.record_best(spec, "cpu", "cuconv_two_stage_pallas")  # stride-1 only
    p = cs.plan(spec, backend="cpu")
    assert p.algorithm != "cuconv_two_stage_pallas"
    assert ex.get(p.algorithm).supports(spec)[0]
    autotune.record_best(spec, "cpu", "gone_executor")
    p = cs.plan(spec, backend="cpu")
    assert p.source in ("heuristic", "cost")


def test_vmem_budget_is_an_executor_declaration():
    """The fused kernel's VMEM model lives on its registry entry; the
    budget guard is its own supports() rule."""
    fused = ex.get("cuconv_pallas")
    small = _spec(SWEEP[0], "float32")
    assert fused.vmem_bytes(small) < ex.FUSED_VMEM_BUDGET
    assert fused.supports(small)[0]
    big = cs.ConvSpec((1, 8, 2100, 1024), (3, 3, 1024, 8),
                      stride=(1, 1), padding=(1, 1))
    assert fused.vmem_bytes(big) > ex.FUSED_VMEM_BUDGET
    ok, why = fused.supports(big)
    assert not ok and "VMEM" in why
    # bf16 halves the working set estimate
    bigb = cs.ConvSpec((1, 8, 2100, 1024), (3, 3, 1024, 8),
                       stride=(1, 1), padding=(1, 1), dtype="bfloat16")
    assert fused.vmem_bytes(bigb) < fused.vmem_bytes(big)


def test_unsupported_dtype_has_clear_error():
    spec = cs.ConvSpec((1, 8, 8, 4), (3, 3, 4, 4), (1, 1), (1, 1),
                       dtype="float16")
    with pytest.raises(ValueError, match="no registered executor"):
        cs.plan(spec)
    # int8 used to be the unsupported example; the quant subsystem's
    # executor claims it now
    spec8 = cs.ConvSpec((1, 8, 8, 4), (3, 3, 4, 4), (1, 1), (1, 1),
                        dtype="int8")
    assert cs.plan(spec8).executor.name == "cuconv_int8"
    with pytest.raises(ValueError, match="dtype"):
        cs.canonical_dtype("not_a_dtype")


# ---------------------------------------------------------------------------
# registry API + third-party executors

def test_registry_lookup_and_registration_errors():
    with pytest.raises(KeyError, match="conv9000"):
        ex.get("conv9000")
    with pytest.raises(KeyError):
        ex.unregister("conv9000")
    with pytest.raises(ValueError, match="already registered"):
        ex.register(ex.LaxExecutor())
    with pytest.raises(ValueError, match="name"):
        ex.register(ex.Executor())                   # no name

    class _Inert(ex.Executor):                       # no fn, no _execute
        name = "inert"
    with pytest.raises(ValueError, match="_execute"):
        ex.register(_Inert())                        # fails at registration
    assert set(ex.registered()) == set(ex.names())
    # ALGORITHMS is the fn-backed back-compat view: fn-less builtins
    # (the int8 executor overrides execute() wholesale) are registered
    # but absent from it
    assert set(ex.ALGORITHMS) <= set(ex.names())
    assert set(ex.names()) - set(ex.ALGORITHMS) == {"cuconv_int8"}
    assert ex.ALGORITHMS["lax"] is cc.conv_lax
    spec = cs.ConvSpec((1, 6, 6, 4), (3, 3, 4, 4), (1, 1), (1, 1))
    assert ex.capable("lax", spec)
    assert not ex.capable("conv9000", spec)       # unknown: False, no raise
    assert not ex.capable("conv1x1_pallas", spec)  # registered, incapable


def test_fn_less_executor_absent_from_algorithms_view():
    """A third-party executor that only implements _execute (fn=None)
    must not break the back-compat mapping view's iterate-then-index
    contract — it is simply absent from the view."""
    class _NoFn(ex.Executor):
        name = "no_fn_fp16"
        dtypes = ("float16",)

        def _execute(self, spec, x, w, bias, interpret):
            return cc.conv_lax(x, w, stride=spec.stride,
                               padding=spec.padding)

    ex.register(_NoFn())
    try:
        assert "no_fn_fp16" in ex.names()
        assert "no_fn_fp16" not in list(ex.ALGORITHMS)
        assert dict(ex.ALGORITHMS)                 # iterate+index never raises
        with pytest.raises(KeyError):
            ex.ALGORITHMS["no_fn_fp16"]
    finally:
        ex.unregister("no_fn_fp16")


class _ToyExecutor(ex.Executor):
    """Third-party executor: fp16-only, supports everything there,
    claims every spec with a paper-beating score."""
    name = "toy_fp16"
    dtypes = ("float16",)

    def heuristic_claim(self, spec, backend):
        return 1000, "toy region"

    def _execute(self, spec, x, w, bias, interpret):
        return cc.conv_lax(x, w, stride=spec.stride, padding=spec.padding,
                           groups=spec.groups)


def test_third_party_executor_participates_everywhere(rng):
    toy = ex.register(_ToyExecutor())
    try:
        spec = cs.ConvSpec((1, 6, 6, 4), (3, 3, 4, 4), (1, 1), (1, 1),
                           dtype="float16")
        # negotiation: only supporter AND highest claim
        p = cs.plan(spec)
        assert (p.algorithm, p.source) == ("toy_fp16", "heuristic")
        # forced resolution through the public string API
        x = jnp.asarray(rng.normal(size=spec.in_shape), jnp.float16)
        w = jnp.asarray(rng.normal(size=spec.filter_shape), jnp.float16)
        got = cc.conv2d(x, w, 1, (1, 1), algorithm="toy_fp16")
        np.testing.assert_allclose(
            np.asarray(got, np.float32),
            np.asarray(cc.conv_lax(x.astype(jnp.float32),
                                   w.astype(jnp.float32), 1, (1, 1))),
            rtol=2e-2, atol=2e-2)
        # measured entries naming it resolve
        autotune.record_best(spec, jax.default_backend(), "toy_fp16")
        assert cs.plan(spec).source == "measured"
    finally:
        ex.unregister("toy_fp16")
    # after unregistration the persisted winner is stale, not a crash
    with pytest.raises(ValueError, match="no registered executor"):
        cs.plan(spec)


class _QuietExecutor(ex.Executor):
    """fp16-capable executor with NO heuristic claim: the cheapest-
    supported cost tier must pick it."""
    name = "quiet_fp16"
    dtypes = ("float16",)

    def _execute(self, spec, x, w, bias, interpret):
        return cc.conv_lax(x, w, stride=spec.stride, padding=spec.padding)


def test_cost_tier_picks_cheapest_supported_when_no_claims():
    ex.register(_QuietExecutor())
    try:
        spec = cs.ConvSpec((1, 6, 6, 4), (3, 3, 4, 4), (1, 1), (1, 1),
                           dtype="float16")
        p = cs.plan(spec)
        assert (p.algorithm, p.source) == ("quiet_fp16", "cost")
        assert "cheapest" in p.reason
    finally:
        ex.unregister("quiet_fp16")


def test_explain_reports_dtype_and_provenance():
    spec = cs.ConvSpec((1, 8, 8, 6), (3, 3, 6, 4), (1, 1), (1, 1),
                       dtype="bfloat16", epilogue="bias_relu")
    p = cs.plan(spec, backend="cpu")
    txt = p.explain()
    assert "dtype=bfloat16" in txt
    assert "accum=float32" in txt
    assert f"[{p.source}]" in txt and p.algorithm in txt
