"""Dry-run machinery unit tests (no 512-device compiles here)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, get_config, list_archs
from repro.launch.dryrun import (apply_overrides, cell_defined,
                                 collective_bytes, probe_variant)
from repro.launch.steps import input_specs
from repro.models.lm import stack_plan


def test_apply_overrides_types():
    cfg = get_config("qwen2-1.5b")
    out = apply_overrides(cfg, ["ce_impl=chunked", "grad_accum=8",
                                "capacity_factor=2.0", "scan_layers=false"])
    assert out.ce_impl == "chunked" and out.grad_accum == 8
    assert out.capacity_factor == 2.0 and out.scan_layers is False


def test_probe_variant_periods():
    for arch in list_archs():
        cfg = get_config(arch)
        pc1, period = probe_variant(cfg, 1)
        pc2, _ = probe_variant(cfg, 2)
        assert pc1.num_layers == period and pc2.num_layers == 2 * period
        assert not pc1.scan_layers and pc1.grad_accum == 1
        # probe stacks must build (stack_plan accepts them)
        stack_plan(pc1), stack_plan(pc2)
        if arch == "jamba-v0.1-52b":
            assert period == 8          # lcm(pattern=8, moe_every=2)


def test_long_500k_skip_policy():
    runs = [a for a in list_archs() if cell_defined(get_config(a),
                                                    "long_500k")]
    assert sorted(runs) == ["jamba-v0.1-52b", "mamba2-1.3b"]


def test_input_specs_shapes():
    for arch in list_archs():
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            spec = input_specs(cfg, shape)
            B = shape.global_batch
            S = shape.seq_len if shape.kind != "decode" else 1
            if cfg.input_mode == "tokens":
                assert spec["tokens"].shape == (B, S)
            else:
                assert spec["embeds"].shape == (B, S, cfg.d_model)
            if cfg.mrope_sections:
                assert spec["positions"].shape == (3, B, S)
            assert ("labels" in spec) == (shape.kind == "train")


def test_collective_parser_ignores_done_and_operands():
    hlo = """
  %all-gather-start.1 = f32[8,8]{1,0} all-gather-start(%x), dims={0}
  %all-gather-done.1 = f32[8,8]{1,0} all-gather-done(%all-gather-start.1)
  %fusion = f32[2,2]{1,0} fusion(%all-reduce.5), calls=%c
"""
    out = collective_bytes(hlo)
    assert out.get("all-gather", {}).get("count") == 1
    assert "all-reduce" not in out          # operand mention only


def test_padded_vocab_divisibility():
    for arch in list_archs():
        cfg = get_config(arch)
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
        assert cfg.padded_vocab % 16 == 0   # TP over vocab on 16-wide axis
