"""MoE router/dispatch invariants (hypothesis + direct)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # deterministic fallback; see _hypothesis_compat
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs.base import get_config, smoke_variant
from repro.nn import moe as M


def _cfg(E=4, K=2, cf=1.25):
    cfg = smoke_variant(get_config("deepseek-moe-16b"))
    return dataclasses.replace(cfg, num_experts=E, experts_per_token=K,
                               capacity_factor=cf)


def test_dropless_equals_manual_topk(rng):
    """Dropless MoE output == explicit per-token top-k expert mixture."""
    cfg = _cfg()
    p = M.moe_init(jax.random.PRNGKey(0), cfg)
    p = jax.tree.map(lambda a: a.astype(jnp.float32), p)
    x = jnp.asarray(rng.normal(size=(2, 6, cfg.d_model)), jnp.float32)
    got, _ = M.moe_fwd(p, cfg, x, dropless=True)

    # manual reference
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    gates, eidx = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / gates.sum(-1, keepdims=True)
    ex = p["experts"]
    outs = []
    for t in range(xt.shape[0]):
        acc = jnp.zeros(cfg.d_model)
        for k in range(cfg.experts_per_token):
            e = int(eidx[t, k])
            h = jax.nn.silu(xt[t] @ ex["wi"][e]) * (xt[t] @ ex["wg"][e])
            acc = acc + gates[t, k] * (h @ ex["wo"][e])
        outs.append(acc)
    want = jnp.stack(outs).reshape(x.shape)
    if cfg.num_shared_experts:
        from repro.nn import layers as L
        want = want + L.mlp_fwd(p["shared"], x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(1, 3), st.integers(0, 2**31 - 1))
def test_gate_mass_conserved(E, K, seed):
    """Renormalized top-k gates sum to 1 per token."""
    K = min(K, E)
    rng = np.random.default_rng(seed)
    probs = jax.nn.softmax(
        jnp.asarray(rng.normal(size=(10, E)), jnp.float32), -1)
    gates, _ = jax.lax.top_k(probs, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)


def test_capacity_drops_reported(rng):
    """With a tiny capacity factor, dropped_frac must be > 0; with
    dropless it must be ~0."""
    cfg = _cfg(cf=0.1)
    p = M.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 32, cfg.d_model)), jnp.float32)
    _, aux_tight = M.moe_fwd(p, cfg, x, dropless=False)
    _, aux_free = M.moe_fwd(p, cfg, x, dropless=True)
    assert float(aux_tight["dropped_frac"]) > 0.0
    assert float(aux_free["dropped_frac"]) == 0.0


def test_group_invariance_when_dropless(rng):
    """Dropless routing is per-token, so grouping must not change outputs."""
    cfg = _cfg()
    p = M.moe_init(jax.random.PRNGKey(0), cfg)
    p = jax.tree.map(lambda a: a.astype(jnp.float32), p)
    x = jnp.asarray(rng.normal(size=(4, 8, cfg.d_model)), jnp.float32)
    y1, _ = M.moe_fwd(p, cfg, x, dropless=True, n_groups=1)
    y2, _ = M.moe_fwd(p, cfg, x, dropless=True, n_groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_load_balance_loss_minimized_by_uniform():
    """The aux loss is minimized (==1 by construction) at uniform routing."""
    E = 8
    me = jnp.full((E,), 1.0 / E)
    ce = jnp.full((E,), 2.0 / E)   # K=2 routed fractions
    uniform = E * jnp.sum(me * ce)
    skew_me = jnp.zeros((E,)).at[0].set(1.0)
    skew_ce = jnp.zeros((E,)).at[0].set(2.0)
    skewed = E * jnp.sum(skew_me * skew_ce)
    assert float(skewed) > float(uniform)
